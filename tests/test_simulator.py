"""Trace-driven simulator: conservation laws, reproducibility, policy
ordering (paper Table VI/VIII structure), typed-action round-trips,
advertised-bandwidth fidelity, fault injection."""
import copy
import dataclasses

import numpy as np
import pytest

from repro.core import (
    ClusterSimulator, SimConfig, generate_jobs, make_policy, generate_trace,
    run_policy_comparison, normalized_table, trace_stats,
)
from repro.core.actions import Defer, Migrate, Pause, Resume, Throttle
from repro.core.orchestrator import FeasibilityConfig, Policy

# 4-day run at the headline job density (240 jobs / 7 days)
FAST = SimConfig(n_jobs=137, days=4, dt_s=120.0, seed=0)

_CACHE = {}


def run(policy_name, cfg=FAST, **kw):
    key = (policy_name, id(cfg) if cfg is not FAST else "fast")
    if cfg is FAST and key in _CACHE:
        return _CACHE[key]
    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed, profile=cfg.trace)
    pol = make_policy(policy_name)
    sim = ClusterSimulator(cfg, pol, traces=traces, jobs=generate_jobs(cfg),
                          oracle_forecast=pol.wants_oracle_forecast, **kw)
    r = sim.run()
    if cfg is FAST:
        _CACHE[key] = r
    return r


def test_all_jobs_complete_and_energy_conserved():
    r = run("static")
    assert r.completed == FAST.n_jobs
    for j in r.jobs:
        assert j.progress_s == pytest.approx(j.compute_s, abs=FAST.dt_s + 1)
    # energy = compute energy + migration energy, split into grid+renewable
    compute_kwh = sum(j.compute_s for j in r.jobs) / 3600 * FAST.p_node_kw
    total = r.grid_kwh + r.renewable_kwh
    assert total == pytest.approx(compute_kwh + r.migration_kwh, rel=0.02)


def test_deterministic_given_seed():
    r1, r2 = run("feasibility-aware"), run("feasibility-aware")
    assert r1.grid_kwh == pytest.approx(r2.grid_kwh)
    assert r1.mean_jct_s == pytest.approx(r2.mean_jct_s)
    assert r1.migrations == r2.migrations


def test_static_has_no_migrations():
    r = run("static")
    assert r.migrations == 0 and r.migration_overhead == 0.0


def test_feasibility_aware_beats_static_on_energy_and_jct():
    rs, rf = run("static"), run("feasibility-aware")
    assert rf.grid_kwh < rs.grid_kwh  # more renewable use
    assert rf.renewable_fraction > rs.renewable_fraction
    assert rf.mean_jct_s < rs.mean_jct_s  # contention-aware placement
    assert rf.migration_overhead < 0.05  # paper: < 2% at 10 Gbps; slack here


def test_energy_only_pays_jct_and_stalls():
    re_, rf = run("energy-only"), run("feasibility-aware")
    assert re_.stall_overhead > rf.stall_overhead
    assert re_.mean_jct_s > rf.mean_jct_s


def test_policy_comparison_table_structure():
    res = {name: run(name) for name in ("static", "energy-only", "feasibility-aware", "oracle")}
    rows = normalized_table(res)
    by = {r["policy"]: r for r in rows}
    assert by["static"]["nonrenew_energy"] == 1.0
    assert by["static"]["jct"] == 1.0
    assert by["feasibility-aware"]["nonrenew_energy"] < 1.0
    assert by["oracle"]["nonrenew_energy"] <= by["feasibility-aware"]["nonrenew_energy"] + 0.05


def test_trace_calibration():
    st = trace_stats(generate_trace(5, 7, seed=0))
    assert 2.5 <= st["mean_h"] <= 6.0  # CAISO band (fn.1: 2.5-9.5 h events)
    assert st["max_h"] <= 9.5 + 1e-6
    assert st["n_windows"] >= 5 * 7 * 0.8  # ~daily windows


def test_fault_injection_checkpoint_restart():
    """Beyond-paper: node failures lose at most checkpoint_interval of work
    and all jobs still finish."""
    cfg = dataclasses.replace(FAST, failure_rate_per_slot_hour=0.05)
    r = run("feasibility-aware", cfg=cfg)
    assert r.failures > 0
    assert r.completed == cfg.n_jobs


# ---------------------------------------------------------------------------
# Golden reproduction gate: the paper-table6 scenario keeps Table VI ordering
# ---------------------------------------------------------------------------


def test_golden_paper_table6_feasibility_beats_energy_only():
    """Under the registered ``paper-table6`` scenario, feasibility-aware must
    stay at or below energy-only on BOTH grid energy and stall overhead
    (Table VI rows 2-3). dt is coarsened to keep the suite fast; trace, job
    mix and WAN are the scenario's."""
    res = run_policy_comparison(
        scenario="paper-table6",
        overrides=dict(dt_s=120.0, wan_gbps=1.0),
        policies=("energy-only", "feasibility-aware"),
    )
    eo, fa = res["energy-only"], res["feasibility-aware"]
    assert fa.grid_kwh <= eo.grid_kwh
    assert fa.stall_overhead <= eo.stall_overhead
    assert fa.completed == eo.completed == 240


def test_policy_configs_reach_comparison_path():
    """Per-policy kwargs (stochastic eps / sigma) flow through
    run_policy_comparison — previously unreachable."""
    res = run_policy_comparison(
        cfg=FAST,
        policies=("static", "feasibility-aware"),
        policy_configs={"feasibility-aware": FeasibilityConfig(
            eps=0.05, forecast_sigma_s=900.0)},
    )
    det = run("feasibility-aware")
    stoch = res["feasibility-aware"]
    # the stochastic gate is strictly more conservative
    assert stoch.migrations <= det.migrations
    assert stoch.completed == FAST.n_jobs


# ---------------------------------------------------------------------------
# Typed actions round-trip through the simulator
# ---------------------------------------------------------------------------


class ScriptedPolicy(Policy):
    """Emits a fixed action sequence, one batch per orchestrator tick."""

    name = "scripted"

    def __init__(self, batches):
        self.batches = list(batches)
        self.seen = []

    def decide(self, state):
        self.seen.append(state)
        return self.batches.pop(0) if self.batches else []


def small_cfg(**kw):
    kw.setdefault("n_jobs", 8)
    kw.setdefault("days", 2)
    kw.setdefault("dt_s", 60.0)
    kw.setdefault("n_sites", 3)
    kw.setdefault("arrival_skew", (0.5, 0.3, 0.2))
    return SimConfig(**kw)


def test_defer_roundtrip_holds_job_out_of_scheduling():
    from repro.core import SimJob

    cfg = SimConfig(n_sites=1, slots_per_site=2, n_jobs=3, days=2, dt_s=60.0,
                    arrival_skew=(1.0,))
    GB = 1e9
    # two blockers fill both slots until t=2h; the target arrives at t=100s
    # and must wait queued — where the policy defers it to t=4h
    jobs = [
        SimJob(0, 0.0, 2 * 3600.0, 1 * GB, "A", 0, site=0),
        SimJob(1, 0.0, 2 * 3600.0, 1 * GB, "A", 0, site=0),
        SimJob(2, 100.0, 3600.0, 1 * GB, "A", 0, site=0),
    ]
    until = 4 * 3600.0

    class DeferTarget(Policy):
        name = "defer-test"

        def decide(self, state):
            if any(jv.jid == 2 for jv in state.queued()):
                return [Defer(2, until)]
            return []

    sim = ClusterSimulator(cfg, DeferTarget(), jobs=jobs)
    r = sim.run()
    j = r.jobs[2]
    assert j.defer_until_s == pytest.approx(until)
    assert j.done_s >= 0
    # without the Defer it would start at ~2h when the blockers finish;
    # with it, not before t=4h (next scheduler pass after the hold expires)
    assert j.started_s >= until
    assert j.started_s <= until + cfg.dt_s * 2


def test_pause_resume_roundtrip():
    cfg = small_cfg()

    class PauseThenResume(Policy):
        name = "pause-test"

        def __init__(self):
            self.paused_jid = None

        def decide(self, state):
            if self.paused_jid is None:
                running = state.running()
                if running:
                    self.paused_jid = running[0].jid
                    return [Pause(self.paused_jid)]
                return []
            paused = [j for j in state.paused() if j.jid == self.paused_jid]
            if paused:
                return [Resume(self.paused_jid)]
            return []

    pol = PauseThenResume()
    sim = ClusterSimulator(cfg, pol, jobs=generate_jobs(cfg))
    r = sim.run()
    assert pol.paused_jid is not None
    j = next(x for x in r.jobs if x.jid == pol.paused_jid)
    assert j.paused_policy_s > 0  # spent time paused
    assert j.done_s >= 0  # and still finished
    assert r.completed == cfg.n_jobs


def test_throttle_roundtrip_scales_power_and_progress():
    cfg = small_cfg()
    base = ClusterSimulator(cfg, make_policy("static"),
                            jobs=generate_jobs(cfg)).run()

    class ThrottleAll(Policy):
        name = "throttle-test"

        def decide(self, state):
            return [Throttle(j.jid, 0.5) for j in state.running()
                    if j.power_frac > 0.5]

    thr = ClusterSimulator(cfg, ThrottleAll(), jobs=generate_jobs(cfg)).run()
    assert thr.completed == cfg.n_jobs
    # throttled fleet takes longer but burns no more total energy
    assert thr.mean_jct_s > base.mean_jct_s
    total_b = base.grid_kwh + base.renewable_kwh
    total_t = thr.grid_kwh + thr.renewable_kwh
    assert total_t == pytest.approx(total_b, rel=0.05)
    for j in thr.jobs:
        assert j.power_frac == 0.5


def test_invalid_actions_rejected_not_applied():
    cfg = small_cfg()
    sim = ClusterSimulator(
        cfg,
        ScriptedPolicy([[
            Migrate(0, 99),  # dest out of range
            Migrate(9999, 1),  # unknown job
            Resume(0),  # not paused
            Throttle(9999, 0.5),  # unknown job
        ]]),
        jobs=generate_jobs(cfg),
    )
    r = sim.run()
    assert r.rejected_actions == 4
    assert r.migrations == 0
    assert r.completed == cfg.n_jobs


def test_legacy_tuple_actions_rejected_not_crash():
    """A pre-redesign policy returning (jid, dest) tuples must not crash
    the run — ill-typed actions count as rejected."""
    cfg = small_cfg()
    r = ClusterSimulator(cfg, ScriptedPolicy([[(0, 1), (1, 2)]]),
                         jobs=generate_jobs(cfg)).run()
    assert r.rejected_actions == 2
    assert r.migrations == 0
    assert r.completed == cfg.n_jobs


def test_cfg_and_scenario_are_mutually_exclusive():
    with pytest.raises(ValueError, match="not both"):
        run_policy_comparison(FAST, scenario="paper-table6")


def test_migrate_inside_cooldown_rejected():
    """The per-job debounce is enforced by the simulator even for policies
    that ignore the `eligible` flag."""
    cfg = small_cfg()

    class ThrashingPolicy(Policy):
        name = "thrash-test"

        def decide(self, state):
            # migrate every running job every tick, cooldown be damned
            return [Migrate(j.jid, (j.site + 1) % len(state.sites))
                    for j in state.running()]

    r = ClusterSimulator(cfg, ThrashingPolicy(), jobs=generate_jobs(cfg)).run()
    assert r.rejected_actions > 0  # post-migration re-migrations were blocked
    for j in r.jobs:
        assert j.done_s >= 0


def test_migrate_action_roundtrip():
    """A forced Migrate of a running job moves it and the job completes at
    the destination."""
    cfg = small_cfg()

    class MigrateFirst(Policy):
        name = "migrate-test"

        def __init__(self):
            self.moved = None

        def decide(self, state):
            if self.moved is None:
                for j in state.migratable():
                    dest = (j.site + 1) % len(state.sites)
                    self.moved = (j.jid, dest)
                    return [Migrate(j.jid, dest)]
            return []

    pol = MigrateFirst()
    r = ClusterSimulator(cfg, pol, jobs=generate_jobs(cfg)).run()
    assert pol.moved is not None
    jid, dest = pol.moved
    j = next(x for x in r.jobs if x.jid == jid)
    assert j.migrations == 1
    assert j.site == dest
    assert j.done_s >= 0


def test_defer_issued_once_per_job_window():
    """Regression (ISSUE 3): DeferToWindowPolicy used to re-issue Defer for
    already-held jobs on every orchestrator tick.  JobView now exposes
    defer_until_s and the policy skips held jobs — a job may only be
    re-deferred after its previous hold expired."""

    class Recording(Policy):
        name = "recording"

        def __init__(self, inner):
            self.inner = inner
            self.log = []

        def decide(self, state):
            acts = self.inner.decide(state)
            self.log.append((state.t, acts))
            return acts

    pol = Recording(make_policy("defer-to-window"))
    # 2 slots/site keeps queues non-empty so Defer actually fires
    ClusterSimulator.from_scenario(
        "paper-table6", pol,
        overrides=dict(days=3, n_jobs=120, slots_per_site=2)).run()
    n_defers = 0
    held_until = {}
    for t, acts in pol.log:
        for a in acts:
            if isinstance(a, Defer):
                n_defers += 1
                prev = held_until.get(a.jid)
                assert prev is None or t >= prev - 1e-9, (
                    f"job {a.jid} re-deferred at t={t} while still held "
                    f"until {prev}")
                held_until[a.jid] = a.until_s
    assert n_defers > 0  # the policy actually fired


def test_snapshot_exposes_defer_until():
    from repro.core.actions import Defer

    cfg = small_cfg()
    sim = ClusterSimulator(cfg, make_policy("static"), jobs=generate_jobs(cfg))
    j = sim.jobs[0]
    sim._move(j, state="queued")
    # through the action path — the simulator mirrors job mutations into
    # its SoA columns at the sanctioned mutation points
    sim._apply_action(Defer(j.jid, 1234.5), 0.0, None, 1e12)
    assert j.defer_until_s == 1234.5
    view = next(v for v in sim.snapshot(0.0).jobs if v.jid == j.jid)
    assert view.defer_until_s == 1234.5
    assert view.held(0.0) and not view.held(2000.0)


def test_post_horizon_arrival_is_failed_migration():
    """Regression (ISSUE 3): the failed-arrival estimate clamped t_arrive to
    horizon - 1, so a transfer landing *after* the horizon was classified
    by whatever the trace's last sample happened to be.  A destination
    window touching the horizon made such transfers count as successes."""
    from repro.core import SimJob
    from repro.core.traces import SiteTrace, Window

    GB = 1e9
    horizon = 1 * 24 * 3600.0
    cfg = SimConfig(n_sites=2, days=1, arrival_skew=(0.5, 0.5), n_jobs=1)
    # dest window covers the last hour right up to the horizon: the old
    # clamp landed inside it and called the migration a success
    traces = [SiteTrace(0, []), SiteTrace(1, [Window(horizon - 3600.0, horizon)])]

    def migrate_at(t, ckpt_gb):
        jobs = [SimJob(0, 0.0, 10 * 3600.0, ckpt_gb * GB, "C", 0, site=0)]
        sim = ClusterSimulator(cfg, make_policy("static"), traces=traces,
                               jobs=jobs)
        j = sim.jobs[0]
        sim._move(j, state="queued")
        sim._move(j, state="running")
        sim._apply_action(Migrate(0, 1), t, None, horizon)
        assert sim.migrations == 1 and sim.rejected_actions == 0
        return sim.failed_migrations

    # 200 GB at 10 Gbps = 160 s: launched 100 s before the horizon it
    # arrives 60 s past it → failed (old code: clamped into the window)
    assert migrate_at(horizon - 100.0, 200.0) == 1
    # control: a small checkpoint arrives inside the window → success
    assert migrate_at(horizon - 600.0, 2.0) == 0


# ---------------------------------------------------------------------------
# Advertised bandwidth matches the transfer loop's NIC-share model
# ---------------------------------------------------------------------------


def test_snapshot_bandwidth_matches_effective_bw():
    """With two in-flight transfers out of one site, the snapshot advertises
    bw/2 (the seed's row/column halving predicted bw/4)."""
    cfg = small_cfg(n_sites=4, arrival_skew=(0.25, 0.25, 0.25, 0.25))
    sim = ClusterSimulator(cfg, make_policy("static"), jobs=generate_jobs(cfg))
    # force two transfers 0->2 and 0->3
    j0, j1 = sim.jobs[0], sim.jobs[1]
    for j, dest in ((j0, 2), (j1, 3)):
        sim._move(j, state="queued", site=0)
        sim._move(j, state="running")
        j.transfer_dest = dest
        j.transfer_remaining_bits = 8.0 * j.ckpt_bytes
        sim._move(j, state="migrating")
    nic = cfg.wan_gbps * 1e9
    eff = sim._effective_bw([j0, j1], 0.0)
    assert eff[j0.jid] == pytest.approx(nic / 2)
    state = sim.snapshot(0.0)
    assert state.bandwidth_bps[0, 1] == pytest.approx(nic / 2)  # same shares
    assert state.bandwidth_bps[0, 2] == pytest.approx(nic / 2)
    assert state.bandwidth_bps[1, 0] == pytest.approx(nic)  # inbound free
    assert state.bandwidth_bps[1, 2] == pytest.approx(nic / 1)  # 1 incoming


def test_flaky_wan_degrades_effective_bandwidth():
    cfg = small_cfg(wan_degrade_prob=1.0, wan_degraded_gbps=0.5)
    sim = ClusterSimulator(cfg, make_policy("static"), jobs=generate_jobs(cfg))
    assert sim._nic_bps(0.0) == pytest.approx(0.5e9)
    state = sim.snapshot(0.0)
    assert float(state.bandwidth_bps.max()) == pytest.approx(0.5e9)
