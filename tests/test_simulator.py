"""Trace-driven simulator: conservation laws, reproducibility, policy
ordering (paper Table VI/VIII structure), fault injection."""
import copy

import numpy as np
import pytest

from repro.core import (
    ClusterSimulator, SimConfig, generate_jobs, make_policy, generate_trace,
    run_policy_comparison, normalized_table, trace_stats,
)

# 4-day run at the headline job density (240 jobs / 7 days)
FAST = SimConfig(n_jobs=137, days=4, dt_s=120.0, seed=0)

_CACHE = {}


def run(policy_name, cfg=FAST, **kw):
    key = (policy_name, id(cfg) if cfg is not FAST else "fast")
    if cfg is FAST and key in _CACHE:
        return _CACHE[key]
    import copy
    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed)
    jobs = generate_jobs(cfg)
    sim = ClusterSimulator(cfg, make_policy(policy_name), traces=traces,
                           jobs=jobs, oracle_forecast=(policy_name == "oracle"), **kw)
    r = sim.run()
    if cfg is FAST:
        _CACHE[key] = r
    return r


def test_all_jobs_complete_and_energy_conserved():
    r = run("static")
    assert r.completed == FAST.n_jobs
    for j in r.jobs:
        assert j.progress_s == pytest.approx(j.compute_s, abs=FAST.dt_s + 1)
    # energy = compute energy + migration energy, split into grid+renewable
    compute_kwh = sum(j.compute_s for j in r.jobs) / 3600 * FAST.p_node_kw
    total = r.grid_kwh + r.renewable_kwh
    assert total == pytest.approx(compute_kwh + r.migration_kwh, rel=0.02)


def test_deterministic_given_seed():
    r1, r2 = run("feasibility-aware"), run("feasibility-aware")
    assert r1.grid_kwh == pytest.approx(r2.grid_kwh)
    assert r1.mean_jct_s == pytest.approx(r2.mean_jct_s)
    assert r1.migrations == r2.migrations


def test_static_has_no_migrations():
    r = run("static")
    assert r.migrations == 0 and r.migration_overhead == 0.0


def test_feasibility_aware_beats_static_on_energy_and_jct():
    rs, rf = run("static"), run("feasibility-aware")
    assert rf.grid_kwh < rs.grid_kwh  # more renewable use
    assert rf.renewable_fraction > rs.renewable_fraction
    assert rf.mean_jct_s < rs.mean_jct_s  # contention-aware placement
    assert rf.migration_overhead < 0.05  # paper: < 2% at 10 Gbps; slack here


def test_energy_only_pays_jct_and_stalls():
    re_, rf = run("energy-only"), run("feasibility-aware")
    assert re_.stall_overhead > rf.stall_overhead
    assert re_.mean_jct_s > rf.mean_jct_s


def test_policy_comparison_table_structure():
    res = {name: run(name) for name in ("static", "energy-only", "feasibility-aware", "oracle")}
    rows = normalized_table(res)
    by = {r["policy"]: r for r in rows}
    assert by["static"]["nonrenew_energy"] == 1.0
    assert by["static"]["jct"] == 1.0
    assert by["feasibility-aware"]["nonrenew_energy"] < 1.0
    assert by["oracle"]["nonrenew_energy"] <= by["feasibility-aware"]["nonrenew_energy"] + 0.05


def test_trace_calibration():
    st = trace_stats(generate_trace(5, 7, seed=0))
    assert 2.5 <= st["mean_h"] <= 6.0  # CAISO band (fn.1: 2.5-9.5 h events)
    assert st["max_h"] <= 9.5 + 1e-6
    assert st["n_windows"] >= 5 * 7 * 0.8  # ~daily windows


def test_fault_injection_checkpoint_restart():
    """Beyond-paper: node failures lose at most checkpoint_interval of work
    and all jobs still finish."""
    cfg = copy.replace(FAST, failure_rate_per_slot_hour=0.05) if hasattr(copy, "replace") else None
    import dataclasses
    cfg = dataclasses.replace(FAST, failure_rate_per_slot_hour=0.05)
    r = run("feasibility-aware", cfg=cfg)
    assert r.failures > 0
    assert r.completed == cfg.n_jobs
