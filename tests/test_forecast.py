"""Forecast-and-planning subsystem: ForecastHorizon construction (noise
determinism, outage compression, horizon gating), ClusterState.forecast
wiring across all three consumers, the plan-ahead policy's stage logic,
the forecastable-brownouts acceptance ordering, and the post-admission
routing checks in dryrun --plan / serve --green-route."""
import dataclasses

import numpy as np
import pytest

from repro.core import ClusterSimulator, make_policy, run_policy_comparison
from repro.core.actions import Defer, Migrate, Pause, Resume
from repro.core.forecast import ForecastHorizon, OutageForecast, WindowForecast
from repro.core.orchestrator import PlanAheadPolicy
from repro.core.scenarios import get_scenario
from repro.core.state import ClusterState, JobView, SiteView
from repro.core.traces import SiteTrace, Window, generate_trace
from repro.core.wan import WanProfile, WanTopology

GB = 1e9
HOUR = 3600.0
DAY = 24 * HOUR


# ---------------------------------------------------------------------------
# ForecastHorizon construction
# ---------------------------------------------------------------------------


def test_build_sigma_zero_reproduces_trace_windows():
    traces = generate_trace(3, 2, seed=0)
    fc = ForecastHorizon.build(traces, sigma_s=0.0)
    for s, tr in enumerate(traces):
        got = [(w.start_s, w.end_s) for w in fc.site_windows[s]]
        want = [(w.start_s, w.end_s) for w in tr.windows]
        assert got == want


def test_window_noise_is_hash_deterministic():
    traces = generate_trace(3, 3, seed=1)
    a = ForecastHorizon.build(traces, sigma_s=900.0, seed=5)
    b = ForecastHorizon.build(traces, sigma_s=900.0, seed=5)
    c = ForecastHorizon.build(traces, sigma_s=900.0, seed=6)
    assert a.site_windows == b.site_windows  # same seed: identical horizon
    assert a.site_windows != c.site_windows  # different seed: jitter moves
    # the jitter is bounded in distribution, not a constant offset
    flat_a = [w.start_s for wins in a.site_windows for w in wins]
    flat_t = [w.start_s for tr in traces for w in tr.windows]
    assert len(flat_a) <= len(flat_t)
    assert any(abs(x - y) > 1.0 for x, y in zip(flat_a, flat_t))


def test_horizon_gates_lookahead():
    tr = SiteTrace(0, [Window(2 * HOUR, 4 * HOUR), Window(30 * HOUR, 33 * HOUR)])
    fc = ForecastHorizon.build([tr], horizon_s=DAY)
    assert fc.next_window_start_s(0, 0.0) == 2 * HOUR
    # at t=3 h the 30 h window is beyond the 24 h lookahead → invisible
    assert fc.next_window_start_s(0, 3 * HOUR) == float("inf")
    # at t=6.5 h it slides into view (6.5 + 24 > 30)
    assert fc.next_window_start_s(0, 6.5 * HOUR) == 30 * HOUR
    assert fc.next_window_start_s(0, 1.0) == 2 * HOUR
    assert fc.next_window(0, 3 * HOUR).start_s == 2 * HOUR  # covering window
    assert fc.next_window_start_s(0, 34 * HOUR) == float("inf")
    # a 6-hour horizon hides the 30 h window even from t=25 h
    short = ForecastHorizon.build([tr], horizon_s=6 * HOUR)
    assert short.next_window_start_s(0, 4.5 * HOUR) == float("inf")
    assert short.next_window_start_s(0, 25 * HOUR) == 30 * HOUR


def test_build_merges_windows_that_overlap_after_jitter():
    """The query surface assumes disjoint windows; overlapping ones (e.g.
    containment produced by edge jitter) must be merged or bisect coverage
    and the green_seconds overlap sum go wrong."""
    tr = SiteTrace(0, [Window(0.0, 10 * HOUR), Window(2 * HOUR, 3 * HOUR)])
    fc = ForecastHorizon.build([tr])
    assert len(fc.site_windows[0]) == 1
    assert fc.active(0, 5 * HOUR)  # mid-span of the containing window
    assert fc.next_window(0, 5 * HOUR).end_s == 10 * HOUR
    assert fc.green_seconds(0, 0.0, 10 * HOUR) == pytest.approx(10 * HOUR)


def test_green_seconds_and_active():
    tr = SiteTrace(0, [Window(HOUR, 2 * HOUR)])
    fc = ForecastHorizon.build([tr])
    assert fc.active(0, 1.5 * HOUR)
    assert not fc.active(0, 0.5 * HOUR)
    assert fc.green_seconds(0, 0.0, 3 * HOUR) == pytest.approx(HOUR)
    assert fc.green_seconds(0, 1.5 * HOUR, 1.75 * HOUR) == pytest.approx(900.0)


def test_fabric_outages_compressed_to_spans():
    prof = WanProfile(gbps=10.0, hourly_degrade_prob=0.5, degraded_gbps=0.5)
    topo = prof.build_topology(3, days=2, seed=3)
    fc = ForecastHorizon.build(generate_trace(3, 2, seed=3), wan=topo)
    assert fc.outages  # the p=0.5 calendar certainly browns out somewhere
    mask = topo.brownout_mask
    for o in fc.outages:
        assert o.fabric_wide
        assert o.capacity_bps == pytest.approx(0.5e9)
        h0, h1 = int(o.start_s // HOUR), int(o.end_s // HOUR)
        assert mask[h0:h1].all()  # span covers only browned hours
        if h0 > 0:
            assert not mask[h0 - 1]  # and is maximal
        if h1 < len(mask):
            assert not mask[h1]


def test_ongoing_outage_does_not_mask_back_to_back_successor():
    """next_outage returns the span still open at t, but arrival checks ask
    for the first START strictly after t — an ongoing brownout must not
    hide the next one from the veto."""
    a = OutageForecast(0.0, HOUR, 0, 1, 0.5e9)  # ongoing at t=600
    b = OutageForecast(2 * HOUR, 3 * HOUR, 0, 1, 0.5e9)
    fc = ForecastHorizon(horizon_s=DAY, sigma_s=0.0,
                         site_windows=((), ()), outages=(a, b))
    t = 600.0
    assert fc.next_outage(0, 1, t) is a  # the open span
    assert fc.next_outage_start_after(0, 1, t) == 2 * HOUR  # the successor
    assert fc.next_outage_start_after(0, 1, 4 * HOUR) == float("inf")
    # also vetoes a plain future outage identically
    assert fc.next_outage_start_after(0, 1, HOUR + 1) == 2 * HOUR


def test_per_link_outages_and_uplink_query():
    prof = WanProfile(gbps=10.0, hourly_degrade_prob=0.3, degraded_gbps=0.25,
                      brownout_scope="per-link")
    topo = prof.build_topology(4, days=2, seed=0)
    fc = ForecastHorizon.build(generate_trace(4, 2, seed=0), wan=topo)
    assert all(not o.fabric_wide for o in fc.outages)
    o = fc.outages[0]
    # the first outage is visible on its link, absent on others
    assert fc.next_outage(o.src, o.dst, o.start_s - 1.0).start_s == o.start_s
    assert fc.capacity_floor_bps(o.src, o.dst, o.start_s, o.end_s) == \
        pytest.approx(o.capacity_bps)
    assert fc.capacity_floor_bps(o.src, o.dst, o.end_s + 1,
                                 o.end_s + 2) >= o.capacity_bps
    # uplink view: the earliest outage out of o.src is at most o.start_s
    assert fc.next_uplink_outage_start_s(o.src, 0.0) <= o.start_s


# ---------------------------------------------------------------------------
# ClusterState wiring: one forecast for simulator / dryrun / serve
# ---------------------------------------------------------------------------


def test_simulator_snapshot_carries_prebuilt_horizon():
    sim = ClusterSimulator.from_scenario(
        "forecastable-brownouts", "static", overrides=dict(days=2, n_jobs=8))
    st = sim.snapshot(0.0)
    assert st.forecast is sim.forecast_horizon
    assert st.forecast.sigma_s == sim.cfg.forecast_sigma_s
    assert st.forecast.outages  # per-link calendar surfaced
    assert st.transfers == ()
    # oracle harness gets the σ=0 horizon
    osim = ClusterSimulator.from_scenario(
        "forecastable-brownouts", "oracle", overrides=dict(days=2, n_jobs=8))
    assert osim.forecast_horizon.sigma_s == 0.0
    tw = get_scenario("forecastable-brownouts").build_traces()[0].windows[0]
    fw = osim.forecast_horizon.site_windows[0][0]
    assert (fw.start_s, fw.end_s) == (tw.start_s, tw.end_s)


def test_dryrun_and_serve_states_carry_forecast():
    from repro.launch.dryrun import plan_orchestration
    from repro.launch.serve import build_serving_state

    state, _ = plan_orchestration("forecastable-brownouts", "plan-ahead",
                                  at_hour=12.0)
    assert isinstance(state.forecast, ForecastHorizon)
    assert state.forecast.outages
    sstate = build_serving_state("forecastable-brownouts", at_hour=12.0)
    assert isinstance(sstate.forecast, ForecastHorizon)
    # both consume the same (σ=0) horizon as the scenario's trace windows
    assert sstate.forecast.site_windows == state.forecast.site_windows


def test_build_without_traces_has_no_forecast():
    sites = [SiteView(0, 4, 0, 0, True, HOUR)]
    st = ClusterState.build(0.0, [], sites, nic_bps=10 * GB)
    assert st.forecast is None


# ---------------------------------------------------------------------------
# plan-ahead policy stages
# ---------------------------------------------------------------------------


def fc_of(windows_per_site, outages=(), horizon_s=DAY):
    return ForecastHorizon(
        horizon_s=horizon_s, sigma_s=0.0,
        site_windows=tuple(tuple(WindowForecast(a, b) for a, b in wins)
                           for wins in windows_per_site),
        outages=tuple(outages))


def state_of(jobs, sites, fc, t=0.0, nic_gbps=10.0, transfers=()):
    wan = WanTopology.uniform(len(sites), nic_gbps * GB)
    return ClusterState.build(t, jobs, sites, wan=wan, transfers=transfers,
                              forecast=fc)


def green(sid, window_h=2.5, busy=0, queued=0, slots=4):
    return SiteView(sid, slots, busy, queued, True, window_h * HOUR)


def dark(sid, busy=0, queued=0, slots=4, next_start=float("inf")):
    return SiteView(sid, slots, busy, queued, False, 0.0,
                    next_window_start_s=next_start)


def test_plan_ahead_pauses_for_forecast_window():
    fc = fc_of([[(HOUR, 4 * HOUR)], []])
    jobs = [JobView(0, 0, 2 * GB, 10 * HOUR)]
    actions = PlanAheadPolicy().decide(state_of(jobs, [dark(0), dark(1)], fc))
    assert Pause(0) in actions


def test_plan_ahead_does_not_pause_without_upcoming_window():
    fc = fc_of([[(30 * HOUR, 33 * HOUR)], []])  # beyond pause_horizon_s
    jobs = [JobView(0, 0, 2 * GB, 10 * HOUR)]
    actions = PlanAheadPolicy().decide(state_of(jobs, [dark(0), dark(1)], fc))
    assert Pause(0) not in actions


def test_plan_ahead_resumes_on_green_or_evaporated_window():
    fc = fc_of([[], []])
    jobs = [JobView(0, 0, 2 * GB, 10 * HOUR, state="paused"),
            JobView(1, 1, 2 * GB, 10 * HOUR, state="paused")]
    actions = PlanAheadPolicy().decide(
        state_of(jobs, [green(0), dark(1)], fc))
    assert Resume(0) in actions  # site went green
    assert Resume(1) in actions  # window evaporated from the forecast
    # still waiting: window pending inside the pause horizon
    fc2 = fc_of([[], [(2 * HOUR, 5 * HOUR)]])
    actions2 = PlanAheadPolicy().decide(
        state_of(jobs[1:], [green(0), dark(1)], fc2))
    assert actions2 == []


def test_plan_ahead_defers_queued_once_per_window():
    fc = fc_of([[(2 * HOUR, 5 * HOUR)], []])
    jobs = [JobView(0, 0, 2 * GB, 10 * HOUR, state="queued")]
    st = state_of(jobs, [dark(0), dark(1)], fc)
    actions = PlanAheadPolicy().decide(st)
    assert Defer(0, 2 * HOUR) in actions
    # already held → not re-issued
    held = [JobView(0, 0, 2 * GB, 10 * HOUR, state="queued",
                    defer_until_s=2 * HOUR)]
    assert PlanAheadPolicy().decide(
        state_of(held, [dark(0), dark(1)], fc)) == []


def test_plan_ahead_hardens_bandwidth_against_forecast_outage():
    """A transfer that would cross a forecast outage on its link is planned
    at the outage's degraded capacity — here that makes it class C."""
    jobs = [JobView(0, 0, 30 * GB, 10 * HOUR)]  # 24 s at 10 Gbps
    sites = [dark(0), green(1, window_h=9.0)]
    clean = fc_of([[], [(0.0, 9 * HOUR)]])
    assert PlanAheadPolicy().decide(
        state_of(jobs, sites, clean)) == [Migrate(0, 1)]
    outage = OutageForecast(10.0, 2 * HOUR, 0, 1, 0.01 * GB)
    hardened = fc_of([[], [(0.0, 9 * HOUR)]], outages=[outage])
    actions = PlanAheadPolicy().decide(state_of(jobs, sites, hardened))
    assert Migrate(0, 1) not in actions


def test_plan_ahead_migrates_through_ongoing_outage_at_degraded_rate():
    """An outage already in progress is baked into the (degraded) rate the
    arrival check uses — it must NOT veto a transfer that is feasible at
    that degraded capacity (only a FUTURE outage start invalidates the
    estimate)."""
    # ongoing fabric-wide brownout to 2.5 Gbps: a 2 GB checkpoint still
    # drains in ~6.4 s, far inside the 8 h destination window
    ongoing = OutageForecast(0.0, 2 * HOUR, -1, -1, 2.5 * GB)
    fc = fc_of([[], [(0.0, 8 * HOUR)]], outages=[ongoing])
    jobs = [JobView(0, 0, 2 * GB, 10 * HOUR)]
    sites = [dark(0), green(1, window_h=8.0)]
    wan = WanTopology.uniform(2, 2.5 * GB)  # the browned-out capacities
    st = ClusterState.build(0.0, jobs, sites, wan=wan, forecast=fc)
    assert PlanAheadPolicy().decide(st) == [Migrate(0, 1)]
    # the same transfer crossing a FUTURE outage start is still refused
    # (at 10 Gbps the 2 GB transfer takes 1.6 s; the outage begins mid-way)
    future = OutageForecast(0.5, 2 * HOUR, -1, -1, 2.5 * GB)
    fc2 = fc_of([[], [(0.0, 8 * HOUR)]], outages=[future])
    st2 = ClusterState.build(0.0, jobs, sites,
                             wan=WanTopology.uniform(2, 10 * GB), forecast=fc2)
    assert Migrate(0, 1) not in PlanAheadPolicy().decide(st2)


def test_plan_ahead_arrival_check_respects_window_end():
    """Feasible by Algorithm 1 (alpha-window) but arriving too close to the
    forecast window end at the post-admission rate → not migrated."""
    jobs = [JobView(0, 0, 30 * GB, 10 * HOUR)]
    sites = [dark(0), green(1, window_h=9.0)]
    fc = fc_of([[], [(0.0, 9 * HOUR)]])
    pol = PlanAheadPolicy(arrival_margin_s=9.1 * HOUR)  # absurd margin
    assert all(not isinstance(a, Migrate) for a in pol.decide(
        state_of(jobs, sites, fc)))


def test_plan_ahead_preemptive_evacuation_before_uplink_outage():
    """A green job that outlives its window migrates early ONLY when the
    forecast says its uplink browns out before the window ends."""
    jobs = [JobView(0, 0, 20 * GB, 10 * HOUR)]  # outlives the 2 h window
    sites = [green(0, window_h=2.0), green(1, window_h=9.0)]
    calm = fc_of([[(0.0, 2 * HOUR)], [(0.0, 9 * HOUR)]])
    assert PlanAheadPolicy().decide(state_of(jobs, sites, calm)) == []
    outage = OutageForecast(HOUR, 5 * HOUR, 0, 1, 0.01 * GB)
    storm = fc_of([[(0.0, 2 * HOUR)], [(0.0, 9 * HOUR)]], outages=[outage])
    assert PlanAheadPolicy().decide(
        state_of(jobs, sites, storm)) == [Migrate(0, 1)]


def test_plan_ahead_without_forecast_degrades_gracefully():
    jobs = [JobView(0, 0, 2 * GB, 10 * HOUR),
            JobView(1, 0, 2 * GB, 10 * HOUR, state="paused")]
    st = state_of([jobs[0]], [dark(0), green(1)], None)
    actions = PlanAheadPolicy().decide(st)
    assert Migrate(0, 1) in actions  # reactive Algorithm 1 still works
    st2 = state_of([jobs[1]], [dark(0), green(1)], None)
    assert Resume(1) in PlanAheadPolicy().decide(st2)  # never strands


# ---------------------------------------------------------------------------
# Acceptance: forecastable-brownouts ordering
# ---------------------------------------------------------------------------


def test_plan_ahead_beats_reactive_policies_on_forecastable_brownouts():
    """ISSUE 3 acceptance: plan-ahead beats defer-to-window AND
    feasibility-aware on grid kWh with no increase in failed migrations."""
    res = run_policy_comparison(
        scenario="forecastable-brownouts",
        overrides=dict(days=4, n_jobs=120),
        policies=("defer-to-window", "feasibility-aware", "plan-ahead"))
    plan = res["plan-ahead"]
    feas = res["feasibility-aware"]
    defer = res["defer-to-window"]
    assert plan.completed == feas.completed == defer.completed == 120
    assert plan.grid_kwh < feas.grid_kwh
    assert plan.grid_kwh < defer.grid_kwh
    assert plan.failed_migrations <= min(feas.failed_migrations,
                                         defer.failed_migrations)
    assert plan.rejected_actions == 0
    # the lookahead verbs actually fired
    assert sum(j.paused_policy_s for j in plan.jobs) > 0


# ---------------------------------------------------------------------------
# Post-admission routing: dryrun --plan and serve --green-route
# ---------------------------------------------------------------------------


def test_plan_drops_migrations_infeasible_at_post_admission_rate():
    """A class-B move that is feasible at the advertised (current-grant)
    rate becomes class C once its own (flows+1) dilution is counted: the
    plan must drop it."""
    from repro.core.scenarios import Scenario, register_scenario
    from repro.core import scenarios as scn_mod
    from repro.core.scenarios import JobMix
    from repro.launch.dryrun import plan_orchestration

    scn = Scenario(name="tmp-admission", description="x",
                   wan=WanProfile(gbps=1.0),
                   jobs=JobMix(frac_a=0.0, frac_b=1.0, size_b_gb=(20.0, 30.0)))
    register_scenario(scn)
    try:
        hour = next(
            h for h in range(6, 72, 2)
            if any(isinstance(a, Migrate) for a in plan_orchestration(
                "tmp-admission", "feasibility-aware", at_hour=h)[1]))
        state, actions = plan_orchestration("tmp-admission",
                                            "feasibility-aware", at_hour=hour)
        mig = next(a for a in actions if isinstance(a, Migrate))
        src = next(j.site for j in state.jobs if j.jid == mig.jid)
        # one in-flight transfer on the same uplink: post-admission rate
        # halves to 0.5 Gbps → 20-30 GB takes 320-480 s → class C
        _, loaded = plan_orchestration("tmp-admission", "feasibility-aware",
                                       at_hour=hour,
                                       transfers=((src, mig.dest),))
        assert mig not in loaded
    finally:
        scn_mod._REGISTRY.pop("tmp-admission", None)


def test_green_route_admission_flips_on_saturated_uplink():
    """asymmetric-uplink: 2.5 Gbps egress. With a 2 Gbps admission floor the
    first remote request fits (2.5/1) but the second would dilute the
    origin NIC to 1.25 Gbps — the verdict flips and it routes elsewhere."""
    from repro.launch.serve import build_serving_state, green_route

    state = build_serving_state("asymmetric-uplink", at_hour=12.0)
    unchecked = green_route(state, 3)
    checked = green_route(state, 3, origin=0, min_gbps=2.0)
    assert len(checked) == 3
    remote = [s for s in checked if s != 0]
    # at most one remote route fits under the 2 Gbps floor
    assert len(remote) <= 1
    assert unchecked != checked  # the admission check changed the verdict


def test_green_route_lookahead_prefers_upcoming_window():
    """ROADMAP PR 3 follow-up: with a lookahead the router consumes
    state.forecast — a dark site whose window opens within the lookahead
    beats a plain grid spill (the request runs mostly inside the window),
    while the reactive default keeps the old least-loaded order."""
    from repro.launch.serve import green_route

    fc = fc_of([[], [], [(HOUR, 5 * HOUR)]])
    sites = [dark(0), green(1, busy=4), dark(2, busy=1)]
    st = state_of([], sites, fc)
    assert green_route(st, 2) == [0, 0]  # reactive: least-loaded spill
    assert green_route(st, 2, lookahead_s=2 * HOUR) == [2, 2]
    # a lookahead too short to reveal the window falls back to the spill
    assert green_route(st, 1, lookahead_s=0.25 * HOUR) == [0]


def test_green_route_spill_breaks_ties_by_carbon():
    """Signal-aware spill: equal-load dark sites order by the current
    carbon signal under a lookahead (cleanest grid first), by sid
    reactively."""
    from repro.core.signals import GridSignals, SignalStack
    from repro.launch.serve import green_route

    edges = np.array([0.0, DAY])
    sig = GridSignals(
        carbon=SignalStack.from_values(edges, [[600.0], [200.0]]),
        price=SignalStack.from_values(edges, [[0.1], [0.1]]))
    fc = ForecastHorizon(horizon_s=DAY, sigma_s=0.0, site_windows=((), ()),
                         outages=(), signals=sig)
    st = state_of([], [dark(0), dark(1)], fc)
    assert green_route(st, 1) == [0]  # reactive: sid tie-break
    assert green_route(st, 1, lookahead_s=HOUR) == [1]  # cleaner grid


def test_green_route_counts_flows_it_already_routed_without_wan():
    """On the legacy nic_bps path (state.wan is None) the admission floor
    must still see the flows this very call created: at nic=10 Gbps and a
    4 Gbps floor only two remote requests fit (10/2 = 5 ≥ 4 but
    10/3 < 4), no matter how many green sites beckon."""
    from repro.launch.serve import green_route

    sites = [dark(0)] + [green(s, window_h=3.0) for s in range(1, 5)]
    st = ClusterState.build(0.0, [], sites, nic_bps=10 * GB)
    routes = green_route(st, 4, origin=0, min_gbps=4.0)
    assert sum(1 for s in routes if s != 0) == 2
    assert routes.count(0) == 2  # the rest stays at the origin


def test_post_admission_bps_dilutes_by_one_flow():
    wan = WanTopology.uniform(3, 10 * GB)
    st = ClusterState.build(0.0, [], [green(0), green(1), green(2)],
                            wan=wan, transfers=((0, 1),))
    # advertised: current grant = full NIC for the single flow
    assert st.bandwidth_bps[0, 1] == pytest.approx(10 * GB)
    # post-admission: the new flow shares the src NIC with the existing one
    assert st.post_admission_bps(0, 2) == pytest.approx(5 * GB)
    assert st.post_admission_bps(2, 1) == pytest.approx(5 * GB)
    assert st.post_admission_bps(2, 0) == pytest.approx(10 * GB)


def test_post_admission_bps_legacy_path_keeps_true_nic_rate():
    """wan=None fallback: when every matrix entry is diluted by flows,
    bandwidth_bps.max() underestimates the NIC — the snapshot records the
    real nic_bps so the (flows+1) count divides the true capacity."""
    sites = [green(0), green(1)]
    st = ClusterState.build(0.0, [], sites, nic_bps=10 * GB,
                            transfers=((0, 1), (0, 1), (1, 0), (1, 0)))
    # both rows fully diluted: the matrix maximum is 5 Gbps, not 10
    assert float(np.asarray(st.bandwidth_bps).max()) == pytest.approx(5 * GB)
    # a third 0->1 flow gets nic/3 of the TRUE 10 Gbps NIC
    assert st.post_admission_bps(0, 1) == pytest.approx(10 * GB / 3)


def test_post_admission_bps_explicit_matrix_capped_by_pair_entry():
    """wan=None with an explicit NON-uniform matrix (tests/replay path):
    the fallback must never advertise the fabric's fastest link for a
    slower pair."""
    bw = np.array([[10.0, 10.0, 1.0],
                   [10.0, 10.0, 1.0],
                   [1.0, 1.0, 10.0]]) * GB
    st = ClusterState.build(0.0, [], [green(0), green(1), green(2)],
                            bandwidth_bps=bw)
    assert st.post_admission_bps(0, 2) == pytest.approx(1 * GB)  # pair cap
    assert st.post_admission_bps(0, 1) == pytest.approx(10 * GB)
