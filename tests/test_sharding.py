"""Sharding rules: divisibility pruning, mesh-axis pruning, duplicate-axis
prevention, param pspecs on real models."""
import jax
import numpy as np
import pytest
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs import ASSIGNED, get_config
from repro.models import build_model
from repro.optim.adamw import init_opt_state
from repro.parallel.sharding import (
    DEFAULT_RULES, force_mesh_axes, logical_spec, param_pspecs, use_rules,
)


class FakeMesh:
    """Carry axis names+sizes without devices (tests run on 1 CPU)."""

    def __init__(self, names, shape):
        self.axis_names = tuple(names)
        self.devices = np.empty(shape)


MESH = FakeMesh(("data", "model"), (16, 16))
MESH3 = FakeMesh(("pod", "data", "model"), (2, 16, 16))


@pytest.mark.parametrize("arch", list(ASSIGNED))
def test_param_pspecs_valid_for_all_archs(arch):
    """Every param leaf gets a spec with (a) rank == ndim, (b) no duplicate
    mesh axis, (c) every sharded dim divisible by the axis size."""
    cfg = get_config(arch)
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    for mesh in (MESH, MESH3):
        specs = param_pspecs(sds, DEFAULT_RULES, mesh)
        sizes = dict(zip(mesh.axis_names, mesh.devices.shape))
        leaves = jax.tree_util.tree_leaves_with_path(
            specs, is_leaf=lambda x: isinstance(x, P)
        )
        assert leaves
        flat_sds = {
            jax.tree_util.keystr(p): l
            for p, l in jax.tree_util.tree_flatten_with_path(sds)[0]
        }
        for path, spec in leaves:
            leaf = flat_sds[jax.tree_util.keystr(path)]
            assert len(spec) <= len(leaf.shape)
            used = []
            for dim, ax in zip(leaf.shape, tuple(spec) + (None,) * (len(leaf.shape) - len(spec))):
                if ax is None:
                    continue
                axes = ax if isinstance(ax, tuple) else (ax,)
                n = int(np.prod([sizes[a] for a in axes]))
                assert dim % n == 0, (arch, jax.tree_util.keystr(path), leaf.shape, spec)
                used.extend(axes)
            assert len(used) == len(set(used)), (arch, path, spec)


def test_opt_state_mirrors_param_sharding():
    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    sds = jax.eval_shape(model.init, jax.random.PRNGKey(0))
    opt_sds = jax.eval_shape(init_opt_state, sds)
    specs = param_pspecs(sds, DEFAULT_RULES, MESH)
    opt_specs = param_pspecs(opt_sds, DEFAULT_RULES, MESH)
    # m / v / master use the same spec tree as the params
    assert opt_specs["m"] == specs
    assert opt_specs["v"] == specs
    assert opt_specs["master"] == specs
    assert opt_specs["step"] == P()


def test_logical_spec_prunes_missing_axes():
    with force_mesh_axes(("data", "model")):
        assert logical_spec("batch", "seq") == P("data", "model")  # pod pruned
    with force_mesh_axes(("pod", "data", "model")):
        assert logical_spec("batch", "seq") == P(("pod", "data"), "model")
    with force_mesh_axes(()):
        pass


def test_rules_override():
    rules = DEFAULT_RULES.with_overrides(seq=None, mlp_act="model")
    with use_rules(rules), force_mesh_axes(("data", "model")):
        assert logical_spec("seq") == P(None)
        assert logical_spec("mlp_act") == P("model")
