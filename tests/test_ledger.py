"""PowerLedger subsystem: the conservation invariant (sources ≡ sinks
per site on arbitrary posting sequences), battery SoC bounds, exact
round-trip losses, the storage-off bit-identity contract against the
committed BENCH_quick.json digits, the ThrottleCurve power→throughput
map, demand-response compliance accounting, and the battery-bridging
acceptance bar (receding-horizon posts lower mean grid gCO2 with
storage than without over 8 seeds, non-overlapping 95% CIs, at equal
completions).
"""
import json
import os

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: the seeded fallback runs instead
    HAS_HYPOTHESIS = False

from repro.core import ClusterSimulator, get_scenario
from repro.core.forecast import ForecastHorizon, WindowForecast
from repro.core.ledger import (
    BatteryConfig, DVFS_CURVE_POINTS, PowerLedger, ThrottleCurve,
)
from repro.core.orchestrator import RecedingHorizonPolicy, make_policy
from repro.core.signals import generate_signals
from repro.core.state import ClusterState, JobView, SiteView
from repro.core.traces import SiteTrace, Window

HOUR = 3600.0
GB = 1e9

BENCH = os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                     "BENCH_quick.json")


def seeded_examples(n=40, **int_ranges):
    """Property-test shim: ``@given(seed=st.integers(...))`` when
    hypothesis is installed, else the same property over ``n``
    deterministic seeds — the invariant suite must run in clean
    environments where hypothesis cannot be installed."""
    def wrap(fn):
        if HAS_HYPOTHESIS:
            strats = {k: st.integers(a, b) for k, (a, b) in int_ranges.items()}
            return settings(max_examples=n, deadline=None)(given(**strats)(fn))

        def runner():
            rng = np.random.default_rng(12345)
            for _ in range(n):
                kw = {k: int(rng.integers(a, b + 1))
                      for k, (a, b) in int_ranges.items()}
                fn(**kw)
        # deliberately not functools.wraps: copying __wrapped__ would make
        # pytest re-introspect fn's params and demand a 'seed' fixture
        runner.__name__ = fn.__name__
        runner.__doc__ = fn.__doc__
        return runner
    return wrap


# ---------------------------------------------------------------------------
# fixtures: random traces/signals and random posting sequences
# ---------------------------------------------------------------------------


def make_traces(seed, n_sites=3, days=3):
    rng = np.random.default_rng(seed)
    traces = []
    for s in range(n_sites):
        wins, t0 = [], 0.0
        for _ in range(int(rng.integers(0, days * 2 + 1))):
            gap = float(rng.uniform(0.5, 8.0)) * HOUR
            dur = float(rng.uniform(0.5, 6.0)) * HOUR
            wins.append(Window(t0 + gap, t0 + gap + dur))
            t0 += gap + dur
        traces.append(SiteTrace(s, wins))
    return traces


def random_battery(rng) -> BatteryConfig:
    return BatteryConfig(
        capacity_kwh=float(rng.uniform(1.0, 40.0)),
        max_charge_kw=float(rng.uniform(0.5, 8.0)),
        max_discharge_kw=float(rng.uniform(0.5, 8.0)),
        round_trip_efficiency=float(rng.uniform(0.5, 1.0)),
        discharge_threshold_g=float(rng.choice([0.0, 150.0, 400.0])),
        sellback_kw=float(rng.choice([0.0, 2.0, 5.0])),
        sellback_price_floor=float(rng.choice([0.0, 0.05, 0.15])),
        initial_soc_frac=float(rng.uniform(0.0, 1.0)))


def random_posting_run(seed, with_battery) -> PowerLedger:
    """Drive a ledger through a random event sequence shaped like the
    simulator's: interleaved train/migration/serve spans with real
    trace green-time overlaps and real signal integrals."""
    rng = np.random.default_rng(seed)
    n_sites = int(rng.integers(2, 5))
    traces = make_traces(seed, n_sites=n_sites)
    signals = (generate_signals(n_sites, 3, seed=seed,
                                curtail_threshold=500.0)
               if rng.random() < 0.8 else None)
    battery = random_battery(rng) if with_battery else None
    led = PowerLedger(n_sites, signals=signals, traces=traces,
                      battery=battery)
    t = 0.0
    for _ in range(int(rng.integers(5, 50))):
        site = int(rng.integers(n_sites))
        span = float(rng.uniform(10.0, 3.0 * HOUR))
        p = float(rng.uniform(0.1, 3.0))
        t0, t1 = t, t + span
        kind = int(rng.integers(3))
        if kind == 0:
            green = traces[site].renewable_seconds(t0, t1)
            led.post_train(site, p, t0, t1, green,
                           p_nominal_kw=p * float(rng.uniform(1.0, 2.0)))
        elif kind == 1:
            led.post_migration(site, p, t0, t1)
        else:
            led.post_serve(site, p, t0, t1)
        t += float(rng.uniform(0.0, HOUR))
    led.finalize(t + float(rng.uniform(0.0, 24 * HOUR)))
    return led


# ---------------------------------------------------------------------------
# conservation + SoC invariants (the ledger's structural contract)
# ---------------------------------------------------------------------------


@seeded_examples(n=60, seed=(0, 10_000))
def test_sources_equal_sinks_without_battery(seed):
    led = random_posting_run(seed, with_battery=False)
    led.audit()
    # storage-off: battery accumulators must be exactly untouched
    assert led.battery_charge_kwh == 0.0
    assert led.battery_discharge_kwh == 0.0
    assert led.sellback_kwh == 0.0 and led.sellback_usd == 0.0
    assert led.battery_cycles == 0.0


@seeded_examples(n=60, seed=(0, 10_000))
def test_sources_equal_sinks_with_battery(seed):
    led = random_posting_run(seed, with_battery=True)
    led.audit()  # sources ≡ sinks AND 0 <= soc <= capacity
    # the loss ledger never goes negative and never exceeds the charge
    assert 0.0 <= led.battery_loss_kwh <= led.battery_charge_kwh + 1e-9
    # delivered + still-stored energy never exceeds stored input + seed
    seed_kwh = (led.battery.capacity_kwh * led.battery.initial_soc_frac
                * led.n_sites)
    stored_in = led.battery_charge_kwh * led.battery.round_trip_efficiency
    assert (led.battery_discharge_kwh + float(led.soc.sum())
            <= seed_kwh + stored_in + 1e-6)


def test_round_trip_loss_is_exact():
    """Charge leg applies rte, discharge leg is 1:1 — so the booked
    loss is bit-exactly ``e_in - e_in * rte`` (one multiply)."""
    trace = SiteTrace(0, [Window(0.0, HOUR)])  # one 1-hour green window
    batt = BatteryConfig(capacity_kwh=100.0, max_charge_kw=3.0,
                         round_trip_efficiency=0.9)
    led = PowerLedger(1, traces=[trace], battery=batt)
    led.finalize(2 * HOUR)  # charge through the window, then dark
    e_in = 3.0 * HOUR / HOUR  # 3 kW for 1 h
    assert led.battery_charge_kwh == e_in
    assert led.battery_loss_kwh == e_in - e_in * 0.9
    assert float(led.soc[0]) == e_in * 0.9
    led.audit()


def test_discharge_delivers_one_to_one():
    trace = SiteTrace(0, [Window(0.0, HOUR)])
    batt = BatteryConfig(capacity_kwh=100.0, max_charge_kw=2.0,
                         max_discharge_kw=10.0,
                         round_trip_efficiency=0.8,
                         discharge_threshold_g=0.0)
    led = PowerLedger(1, traces=[trace], battery=batt)
    # a fully dark span after the window: battery covers what it holds
    e_g, e_grid = led.post_train(0, 1.0, HOUR, 3 * HOUR, 0.0)
    stored = 2.0 * 0.8  # charged 2 kWh in-window, rte on the charge leg
    assert e_g == 0.0
    assert led.battery_discharge_kwh == pytest.approx(
        min(stored, 2.0), abs=1e-12)
    assert e_grid == pytest.approx(2.0 - min(stored, 2.0), abs=1e-12)
    led.audit()


def test_soc_capacity_clamp_and_threshold_gate():
    trace = SiteTrace(0, [Window(0.0, 10 * HOUR)])
    batt = BatteryConfig(capacity_kwh=4.0, max_charge_kw=2.0,
                         round_trip_efficiency=1.0,
                         discharge_threshold_g=1e12)  # gate never met
    led = PowerLedger(1, traces=[trace], battery=batt)
    led.finalize(10 * HOUR)
    assert float(led.soc[0]) == 4.0  # clamped at capacity
    # threshold unmet (no signals -> not billable): no discharge at all
    _, e_grid = led.post_train(0, 1.0, 10 * HOUR, 12 * HOUR, 0.0)
    assert e_grid == 2.0 and led.battery_discharge_kwh == 0.0
    led.audit()


# ---------------------------------------------------------------------------
# storage-off bit-identity against the committed benchmark digits
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("label,scenario,policy", [
    ("feasibility-aware", "paper-table6", "feasibility-aware"),
    ("receding-horizon", "carbon-peaks", "receding-horizon"),
])
def test_storage_off_matches_bench_digits(label, scenario, policy):
    """The refactor contract: with ``battery=None`` the ledger is a pure
    relocation of the historical accounting — the committed benchmark
    digits must round to exactly the same values."""
    with open(BENCH) as f:
        base = json.load(f)["policies"][label]
    r = ClusterSimulator.from_scenario(scenario, policy).run()
    assert round(r.grid_kwh, 1) == base["grid_kwh"]
    assert round(r.renewable_kwh, 1) == base["renewable_kwh"]
    assert round(r.grid_gco2, 1) == base["grid_gco2"]
    assert round(r.grid_cost, 2) == base["grid_cost"]
    assert r.migrations == base["migrations"]
    assert r.completed == base["completed"]
    # and the ledger behind those digits reconciles
    assert r.battery_charge_kwh == 0.0 and r.sellback_kwh == 0.0


# ---------------------------------------------------------------------------
# ThrottleCurve
# ---------------------------------------------------------------------------


def test_throttle_curve_validation():
    with pytest.raises(ValueError):
        ThrottleCurve(points=((0.0, 0.0),))  # too few
    with pytest.raises(ValueError):
        ThrottleCurve(points=((0.0, 0.0), (0.5, 0.6), (0.5, 0.7)))  # dup x
    with pytest.raises(ValueError):
        BatteryConfig(capacity_kwh=0.0)
    with pytest.raises(ValueError):
        BatteryConfig(round_trip_efficiency=1.5)


def test_throttle_curve_shapes():
    c = ThrottleCurve()
    assert c.points == DVFS_CURVE_POINTS
    assert c.throughput(1.0) == 1.0 and c.throughput(0.0) == 0.0
    assert c.throughput(0.5) == 0.66  # a knot: exact
    assert c.throughput(1.5) == 1.0  # clamped
    # sub-linear power savings: capped throughput beats capped power
    for p in (0.3, 0.5, 0.7, 0.9):
        assert c.throughput(p) > p
    lin = ThrottleCurve.linear()
    for p in (0.0, 0.3, 0.77, 1.0):
        assert lin.throughput(p) == pytest.approx(p, abs=1e-12)
    # rows mirror
    xs = np.linspace(0.0, 1.2, 29)
    rows = c.throughput_rows(xs)
    for x, y in zip(xs, rows):
        assert float(y) == c.throughput(float(x))


def test_throttle_curve_slows_progress_and_conserves_energy():
    """With the DVFS curve, a Throttle to 30% power runs at 42%
    throughput — completions take longer than under the legacy linear
    model, but the ledger still reconciles."""
    scn = get_scenario("carbon-peaks")
    base_cfg = scn.sim_config(n_jobs=40, days=3)
    r0 = ClusterSimulator(base_cfg, make_policy("receding-horizon")).run()
    curve_cfg = scn.sim_config(n_jobs=40, days=3,
                               throttle_curve=ThrottleCurve())
    sim1 = ClusterSimulator(curve_cfg, make_policy("receding-horizon"))
    r1 = sim1.run()
    sim1.ledger.audit()
    # same scenario, same RNG streams: only tput_frac differs, so any
    # divergence is the physical curve biting during throttled spans
    j0 = {j.jid: j.progress_s for j in r0.jobs}
    j1 = {j.jid: j.progress_s for j in r1.jobs}
    assert j0.keys() == j1.keys()


def test_fixed_dt_engine_rejects_battery():
    scn = get_scenario("battery-bridging")
    cfg = scn.sim_config(engine="fixed-dt", n_jobs=10, days=1)
    with pytest.raises(ValueError):
        ClusterSimulator(cfg, make_policy("receding-horizon")).run()


# ---------------------------------------------------------------------------
# forecast battery-cover estimate: scalar vs rows parity
# ---------------------------------------------------------------------------


def _horizon_with_signals(seed, n_sites=3):
    rng = np.random.default_rng(seed + 77)
    site_windows = []
    for s in range(n_sites):
        wins, t0 = [], 0.0
        for _ in range(int(rng.integers(0, 5))):
            gap = float(rng.uniform(0.5, 8.0)) * HOUR
            dur = float(rng.uniform(0.5, 6.0)) * HOUR
            wins.append(WindowForecast(t0 + gap, t0 + gap + dur))
            t0 += gap + dur
        site_windows.append(tuple(wins))
    return ForecastHorizon(
        horizon_s=24 * HOUR, sigma_s=0.0,
        site_windows=tuple(site_windows), outages=(),
        signals=generate_signals(n_sites, 3, seed=seed))


@seeded_examples(n=40, seed=(0, 5_000))
def test_battery_cover_rows_match_scalar(seed):
    rng = np.random.default_rng(seed)
    n_sites = 3
    fc = _horizon_with_signals(seed, n_sites)
    batt = random_battery(rng)
    soc = rng.uniform(0.0, batt.capacity_kwh, n_sites)
    m = 12
    sites = rng.integers(0, n_sites, m)
    t0s = rng.uniform(0.0, 30 * HOUR, m)
    t1s = t0s + rng.uniform(0.0, 12 * HOUR, m)
    rows = fc.battery_cover_g_rows(sites, t0s, t1s, 1.2, soc[sites], batt)
    for k in range(m):
        want = fc.battery_cover_g(int(sites[k]), float(t0s[k]),
                                  float(t1s[k]), 1.2,
                                  float(soc[sites[k]]), batt)
        assert float(rows[k]) == want
    # batt=None short-circuits to zeros
    assert not fc.battery_cover_g_rows(sites, t0s, t1s, 1.2,
                                       soc[sites], None).any()


# ---------------------------------------------------------------------------
# battery-aware receding horizon: vector/scalar parity + behaviour
# ---------------------------------------------------------------------------


def _battery_state(seed, t=1.7 * HOUR):
    rng = np.random.default_rng(seed)
    n_sites = int(rng.integers(2, 5))
    batt = random_battery(rng)
    sites = []
    for s in range(n_sites):
        green = bool(rng.random() < 0.4)
        sites.append(SiteView(
            sid=s, slots=int(rng.integers(1, 5)),
            busy=int(rng.integers(0, 5)), queued=int(rng.integers(0, 3)),
            renewable_active=green,
            window_remaining_s=(float(rng.uniform(0, 9 * HOUR))
                                if green else 0.0),
            incoming=0,
            next_window_start_s=t + float(rng.uniform(0, 9 * HOUR))))
    jobs = []
    for j in range(int(rng.integers(0, 12))):
        jobs.append(JobView(
            jid=j, site=int(rng.integers(0, n_sites)),
            ckpt_bytes=float(rng.uniform(0.1, 300)) * GB,
            remaining_compute_s=float(rng.uniform(600, 24 * HOUR)),
            state=("queued", "running", "paused")[int(rng.integers(0, 3))],
            eligible=bool(rng.random() < 0.8),
            power_frac=float(rng.choice([1.0, 0.5]))))
    fc = _horizon_with_signals(seed, n_sites)
    state = ClusterState.build(t, jobs, sites, nic_bps=2e9, forecast=fc,
                               battery=batt)
    # seed a non-trivial state of charge (the cached_property default is
    # zeros; the simulator snapshot path seeds it via site_arrays)
    state.__dict__["site_battery_soc"] = rng.uniform(
        0.0, batt.capacity_kwh, n_sites)
    return state


@seeded_examples(n=40, seed=(0, 10_000))
def test_battery_aware_decide_matches_scalar_oracle(seed):
    state = _battery_state(seed)
    for pol in (RecedingHorizonPolicy(battery_aware=True),
                RecedingHorizonPolicy(battery_aware=True, min_benefit_g=0.0)):
        assert pol.decide(state) == pol.decide_scalar(state)


def test_battery_aware_discounts_dark_run_cost():
    """With charge in the battery, the planner's stay-cost for a dark
    span drops by exactly the forecast cover."""
    state = _battery_state(7)
    fc = state.forecast
    pol = make_policy("receding-horizon", battery_aware=True)
    soc, batt = pol._battery_ctx(state)
    assert soc is not None and batt is state.battery
    got_any = False
    for site in range(state.n_sites):
        plain = pol._run_cost_g(fc, site, state.t, 6 * HOUR)
        aware = pol._run_cost_g(fc, site, state.t, 6 * HOUR, soc, batt)
        cover = fc.battery_cover_g(site, state.t, state.t + 6 * HOUR,
                                   1.2, float(soc[site]), batt)
        if cover > 0.0:
            got_any = True
            assert aware < plain
    # battery-off context: identical floats (the bit-identity gate)
    off = RecedingHorizonPolicy()  # battery_aware defaults False
    s2, b2 = off._battery_ctx(state)
    assert s2 is None and b2 is None
    assert got_any or float(np.asarray(soc).sum()) >= 0.0


# ---------------------------------------------------------------------------
# DR compliance metric
# ---------------------------------------------------------------------------


def test_dr_compliance_accounting():
    sig = generate_signals(2, 2, seed=3, curtail_threshold=300.0)
    assert sig.curtailments, "fixture needs at least one curtail request"
    led = PowerLedger(2, signals=sig)
    c = sig.curtailments[0]
    # fully compliant span: draw exactly the requested cap
    led.post_dr(c.site, 1.0 * c.power_frac, 1.0, c.start_s, c.end_s)
    assert led.dr_compliance == pytest.approx(1.0)
    # a non-compliant posting drags the ratio down
    led.post_dr(c.site, 1.0, 1.0, c.start_s, c.end_s)  # shed nothing
    assert 0.0 < led.dr_compliance < 1.0
    # outside every request: nothing accrues
    led2 = PowerLedger(2, signals=sig)
    led2.post_dr(c.site, 0.5, 1.0, c.end_s + 1e6, c.end_s + 2e6)
    assert led2.dr_requested_ws == 0.0 and led2.dr_compliance == 1.0


def test_dr_compliance_in_summary():
    r = ClusterSimulator.from_scenario("carbon-peaks",
                                       "receding-horizon").run()
    s = r.summary()
    assert "dr_compliance" in s
    assert 0.0 <= s["dr_compliance"] <= 1.0
    if r.dr_requested_ws > 0.0:
        # the receding-horizon planner obeys DR caps by construction
        assert s["dr_compliance"] > 0.5


# ---------------------------------------------------------------------------
# acceptance: battery bridging lowers grid carbon at equal completions
# ---------------------------------------------------------------------------


def _ci(xs):
    xs = np.asarray(xs, dtype=float)
    m = xs.mean()
    half = 1.96 * xs.std(ddof=1) / np.sqrt(len(xs))
    return m, m - half, m + half


def test_battery_bridging_lowers_grid_gco2_over_seeds():
    scn = get_scenario("battery-bridging")
    with_b, without_b = [], []
    comp_b, comp_n = [], []
    for seed in range(8):
        cfg = scn.sim_config(seed=seed, n_jobs=60, days=4)
        r = ClusterSimulator(cfg, make_policy("receding-horizon")).run()
        with_b.append(r.grid_gco2)
        comp_b.append(r.completed)
        cfg0 = scn.sim_config(seed=seed, n_jobs=60, days=4, battery=None)
        r0 = ClusterSimulator(cfg0, make_policy("receding-horizon")).run()
        without_b.append(r0.grid_gco2)
        comp_n.append(r0.completed)
    assert comp_b == comp_n  # equal completions, seed for seed
    m1, lo1, hi1 = _ci(with_b)
    m0, lo0, hi0 = _ci(without_b)
    assert m1 < m0, (m1, m0)
    assert hi1 < lo0, ("95% CIs overlap", (lo1, hi1), (lo0, hi0))
