"""Optimizer, schedule, gradient compression, data pipeline."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.data.pipeline import SyntheticLMDataset
from repro.optim import (
    AdamWConfig, apply_updates, compress_roundtrip, cosine_schedule,
    global_norm, init_opt_state,
)


def test_adamw_minimizes_quadratic():
    target = jnp.array([1.0, -2.0, 3.0])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0)
    for _ in range(300):
        grads = {"w": 2 * (params["w"] - target)}
        params, state, m = apply_updates(params, grads, state, cfg)
    np.testing.assert_allclose(np.asarray(params["w"]), np.asarray(target), atol=1e-2)
    assert int(state["step"]) == 300


def test_adamw_mixed_precision_master():
    """bf16 params keep a f32 master: tiny updates are not lost to bf16
    rounding."""
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = init_opt_state(params)
    assert state["master"]["w"].dtype == jnp.float32
    cfg = AdamWConfig(lr=1e-5, weight_decay=0.0)
    p, s, _ = apply_updates(params, {"w": jnp.ones(4, jnp.float32)}, state, cfg)
    assert p["w"].dtype == jnp.bfloat16
    assert float(jnp.max(jnp.abs(s["master"]["w"] - 1.0))) > 0  # master moved


def test_grad_clipping():
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)
    cfg = AdamWConfig(lr=1.0, clip_norm=1.0, weight_decay=0.0)
    huge = {"w": jnp.full(3, 1e6)}
    _, _, m = apply_updates(params, huge, state, cfg)
    assert float(m["grad_norm"]) > 1e6  # reported pre-clip


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, warmup=10, total=100)) == 0.0
    assert float(cosine_schedule(10, warmup=10, total=100)) == pytest.approx(1.0)
    assert float(cosine_schedule(100, warmup=10, total=100)) == pytest.approx(0.1, rel=1e-3)
    # monotone decay after warmup
    vals = [float(cosine_schedule(s, warmup=10, total=100)) for s in range(10, 101, 10)]
    assert all(a >= b for a, b in zip(vals, vals[1:]))


def test_compress_roundtrip_bounded_error():
    g = {"a": jax.random.normal(jax.random.PRNGKey(0), (4096,)) * 0.01,
         "small": jnp.ones((4,)),  # < block: passthrough
         "i": jnp.arange(300, dtype=jnp.int32)}
    out = compress_roundtrip(g)
    err = float(jnp.max(jnp.abs(out["a"] - g["a"])))
    amax = float(jnp.max(jnp.abs(g["a"])))
    assert err <= amax / 127
    np.testing.assert_array_equal(np.asarray(out["small"]), np.asarray(g["small"]))
    np.testing.assert_array_equal(np.asarray(out["i"]), np.asarray(g["i"]))


def test_dataset_deterministic_and_resumable():
    ds = SyntheticLMDataset(1000, 32, 4, seed=5)
    b1, b2 = ds.batch(17), ds.batch(17)
    np.testing.assert_array_equal(b1["tokens"], b2["tokens"])
    b3 = ds.batch(18)
    assert not np.array_equal(b1["tokens"], b3["tokens"])
    # labels are next-token shifted
    np.testing.assert_array_equal(b1["tokens"][:, 1:], b1["labels"][:, :-1])


def test_dataset_learnable_structure():
    """Most transitions follow the affine recurrence (the model can learn)."""
    ds = SyntheticLMDataset(1000, 256, 8, seed=0, p_noise=0.1)
    b = ds.batch(0)
    pred = (ds.a * b["tokens"] + ds.b) % ds.vocab_size
    frac = (pred == b["labels"]).mean()
    assert 0.85 <= frac <= 0.95
