"""Per-kernel allclose sweeps against the pure-jnp oracles (interpret mode
executes the Pallas kernel body on CPU, per the assignment)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: deterministic sweeps still run
    HAS_HYPOTHESIS = False

from repro.kernels import ops, ref
from repro.kernels.flash_attention import flash_attention_pallas
from repro.kernels.quantize import dequantize_int8_pallas, quantize_int8_pallas


def _qkv(key, b, s, t, nh, nkv, hd, dtype):
    ks = jax.random.split(key, 3)
    q = jax.random.normal(ks[0], (b, s, nh, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, t, nkv, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, t, nkv, hd), jnp.float32).astype(dtype)
    return q, k, v


SWEEP = [
    # (s, t, nh, nkv, hd, mask, window, softcap, dtype, tol)
    (128, 128, 4, 4, 64, "causal", 0, 0.0, jnp.float32, 2e-6),
    (256, 256, 4, 2, 64, "causal", 0, 0.0, jnp.float32, 2e-6),
    (256, 256, 8, 1, 128, "causal", 0, 0.0, jnp.float32, 2e-6),
    (512, 512, 4, 2, 128, "window", 128, 0.0, jnp.float32, 2e-6),
    (256, 256, 2, 2, 256, "window", 4096, 0.0, jnp.float32, 2e-6),  # win > seq
    (128, 128, 4, 4, 64, "full", 0, 0.0, jnp.float32, 2e-6),
    (256, 256, 8, 4, 64, "causal", 0, 50.0, jnp.float32, 2e-6),  # gemma softcap
    (256, 256, 4, 4, 128, "causal", 0, 0.0, jnp.bfloat16, 2e-2),
    (512, 512, 6, 6, 64, "window", 256, 30.0, jnp.bfloat16, 2e-2),
]


@pytest.mark.parametrize("s,t,nh,nkv,hd,mask,win,cap,dtype,tol", SWEEP)
def test_flash_attention_matches_oracle(s, t, nh, nkv, hd, mask, win, cap, dtype, tol):
    q, k, v = _qkv(jax.random.PRNGKey(0), 2, s, t, nh, nkv, hd, dtype)
    got = flash_attention_pallas(
        q, k, v, mask_kind=mask, window=win, attn_softcap=cap, interpret=True
    )
    want = ref.flash_attention_ref(q, k, v, mask_kind=mask, window=win, attn_softcap=cap)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(want, np.float32), atol=tol, rtol=tol
    )


def test_flash_attention_block_shapes():
    """Non-default BlockSpec tilings stay correct."""
    q, k, v = _qkv(jax.random.PRNGKey(1), 1, 512, 512, 4, 2, 64, jnp.float32)
    want = ref.flash_attention_ref(q, k, v, mask_kind="causal")
    for bq, bk in [(128, 128), (256, 512), (512, 256)]:
        got = flash_attention_pallas(
            q, k, v, mask_kind="causal", block_q=bq, block_k=bk, interpret=True
        )
        np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=2e-6, rtol=2e-6)


def test_ops_dispatch_ref_on_cpu():
    """On this CPU container the default impl must be the oracle itself."""
    q, k, v = _qkv(jax.random.PRNGKey(2), 1, 64, 64, 2, 2, 32, jnp.float32)
    got = ops.flash_attention(q, k, v, mask_kind="causal")
    want = ref.flash_attention_ref(q, k, v, mask_kind="causal")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=0, rtol=0)


@pytest.mark.parametrize("n,dtype", [
    (256 * 64, jnp.float32),
    (256 * 64 * 4, jnp.float32),
    (256 * 128, jnp.bfloat16),
])
def test_quantize_matches_oracle(n, dtype):
    x = (jax.random.normal(jax.random.PRNGKey(3), (n,), jnp.float32) * 3).astype(dtype)
    q_p, s_p = quantize_int8_pallas(x, interpret=True)
    q_r, s_r = ref.quantize_int8_ref(x)
    assert (np.asarray(q_p) == np.asarray(q_r)).mean() > 0.999  # rounding ties
    np.testing.assert_allclose(np.asarray(s_p), np.asarray(s_r), rtol=1e-6)
    # dequant kernels must agree exactly on identical inputs
    x_p = dequantize_int8_pallas(q_r, s_r, interpret=True)
    x_r = ref.dequantize_int8_ref(q_r, s_r)
    np.testing.assert_allclose(np.asarray(x_p), np.asarray(x_r), atol=1e-6)


if HAS_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(1, 64), st.floats(0.01, 100.0))
    def test_quantize_error_bound(blocks, scale_mag):
        """|x - dq(q(x))| <= amax/254 per block — the int8 quantization error
        bound that makes checkpoint compression training-safe."""
        n = 256 * blocks
        x = jax.random.normal(jax.random.PRNGKey(blocks), (n,), jnp.float32) * scale_mag
        q, s = ref.quantize_int8_ref(x)
        xd = ref.dequantize_int8_ref(q, s)
        err = np.abs(np.asarray(xd - x)).reshape(blocks, 256)
        amax = np.abs(np.asarray(x)).reshape(blocks, 256).max(axis=1)
        bound = amax / 254 + 1e-7
        assert (err.max(axis=1) <= bound + 1e-6 * amax).all()
else:
    @pytest.mark.skip(reason="hypothesis not installed; property tests inactive")
    def test_quantize_error_bound():
        pass


def test_quantize_zero_block():
    x = jnp.zeros((512,), jnp.float32)
    q, s = ref.quantize_int8_ref(x)
    assert (np.asarray(q) == 0).all()
    xd = ref.dequantize_int8_ref(q, s)
    assert (np.asarray(xd) == 0).all()
