"""End-to-end system behaviour: train -> checkpoint -> preempt -> migrate ->
resume on another 'site'; loss decreases; feasibility gates hold through the
whole stack."""
import dataclasses
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import feasibility as fz
from repro.core.migration import migrate_job
from repro.data.pipeline import SyntheticLMDataset
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainStepConfig


def make_trainer(tmp_path, site="siteA", steps=30, seed=0, ckpt_mode="full",
                 grad_compress=False):
    cfg = get_config("micro-lm").reduced()
    model = build_model(cfg)
    data = SyntheticLMDataset(cfg.vocab_size, 32, 4, seed=seed)
    ckpt = CheckpointManager(os.path.join(str(tmp_path), site), job="job0")
    return Trainer(
        model, data, ckpt,
        TrainerConfig(
            total_steps=steps, save_every=10, log_every=5, ckpt_mode=ckpt_mode,
            step_cfg=TrainStepConfig(
                opt=AdamWConfig(lr=3e-3), total_steps=steps, warmup_steps=3,
                grad_compress=grad_compress,
            ),
        ),
    )


def test_training_loss_decreases(tmp_path):
    tr = make_trainer(tmp_path, steps=40)
    status = tr.run()
    assert status["status"] == "done"
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] - 0.3, losses


def test_preemption_checkpoints_and_restart(tmp_path):
    tr = make_trainer(tmp_path, steps=50)
    tr.preempt_signal = lambda step: step >= 17  # window closes at step 17
    status = tr.run()
    assert status["status"] == "preempted"
    assert status["step"] == 17
    # crash-restart: a fresh trainer restores and continues
    tr2 = make_trainer(tmp_path, steps=50)
    step = tr2.restore()
    assert step == 17
    status2 = tr2.run()
    assert status2["status"] == "done" and status2["step"] == 50


def test_restart_equals_uninterrupted(tmp_path):
    """Checkpoint/restart is bitwise-transparent: interrupted+resumed
    training equals the uninterrupted run (same data stream by step)."""
    tr_ref = make_trainer(tmp_path, site="ref", steps=20)
    tr_ref.run()
    tr_a = make_trainer(tmp_path, site="ab", steps=20)
    tr_a.preempt_signal = lambda step: step >= 10
    tr_a.run()
    tr_b = make_trainer(tmp_path, site="ab", steps=20)
    tr_b.restore()
    tr_b.run()
    for a, b in zip(jax.tree.leaves(tr_ref.params), jax.tree.leaves(tr_b.params)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-6)


def test_full_migration_cycle(tmp_path):
    """The paper's end-to-end story on real training state: train at site A,
    renewable window closes -> checkpoint -> feasibility-check -> WAN
    transfer -> restore at site B -> finish. Final state identical to an
    unmigrated run."""
    # reference: uninterrupted
    ref = make_trainer(tmp_path, site="ref", steps=24)
    ref.run()

    # site A: preempted at step 12
    a = make_trainer(tmp_path, site="A", steps=24)
    a.preempt_signal = lambda step: step >= 12
    sa = a.run()
    assert sa["status"] == "preempted"

    # orchestrator decision on the MEASURED checkpoint
    S = a.ckpt.latest_bytes
    v = fz.evaluate(S, 10e9, 2.5 * 3600)
    assert bool(v.feasible)

    dst_mgr, report = migrate_job(a.ckpt, os.path.join(str(tmp_path), "B"),
                                  bandwidth_bps=10e9, window_s=2.5 * 3600)
    assert report.feasible_in_window and report.workload_class == 0

    # site B: restore and finish
    b = make_trainer(tmp_path, site="B", steps=24)
    b.ckpt = dst_mgr
    assert b.restore() == 12
    sb = b.run()
    assert sb["status"] == "done" and sb["step"] == 24
    for x, y in zip(jax.tree.leaves(ref.params), jax.tree.leaves(b.params)):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), atol=1e-6)


def test_int8_checkpoint_still_trains(tmp_path):
    """Compressed (int8) checkpoints lose precision but training continues
    and converges after restore — the paper's §VIII envelope expansion is
    safe."""
    a = make_trainer(tmp_path, site="A8", steps=40, ckpt_mode="int8")
    a.preempt_signal = lambda step: step >= 20
    a.run()
    b = make_trainer(tmp_path, site="A8", steps=40, ckpt_mode="int8")
    b.restore()
    status = b.run()
    assert status["status"] == "done"
    losses = [h["loss"] for h in b.history]
    assert losses[-1] < 5.0  # still learning after lossy restore


def test_grad_compress_trains(tmp_path):
    tr = make_trainer(tmp_path, site="gc", steps=30, grad_compress=True)
    status = tr.run()
    losses = [h["loss"] for h in tr.history]
    assert losses[-1] < losses[0] - 0.2


def test_serve_decode_runs():
    from repro.launch.serve import greedy_decode

    cfg = get_config("micro-lm").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    prompt = jax.random.randint(jax.random.PRNGKey(1), (2, 4), 0, cfg.vocab_size)
    seqs = greedy_decode(model, params, prompt, max_new=6, cache_len=10)
    assert seqs.shape == (2, 10)


def test_serve_green_routing_uses_shared_state():
    """Serve-layer routing builds the same ClusterState snapshot as the
    simulator and fills renewable capacity before spilling to grid sites."""
    from repro.launch.serve import build_serving_state, green_route

    state = build_serving_state("solar-heavy", at_hour=13.0)
    assert len(state.sites) == 5
    routes = green_route(state, 16)
    assert len(routes) == 16
    green = {s.sid for s in state.sites if s.renewable_active}
    free_green_slots = sum(s.slots - s.busy for s in state.sites
                           if s.renewable_active)
    head = routes[:min(16, free_green_slots)]
    assert green, "solar-heavy at 13:00 must have at least one green site"
    assert all(sid in green for sid in head)


def test_orchestration_plan_preview():
    """The dry-run planner produces typed actions from a scenario snapshot
    without running the simulator."""
    from repro.core.actions import Action
    from repro.launch.dryrun import plan_orchestration

    state, actions = plan_orchestration("paper-table6", "feasibility-aware",
                                        at_hour=36.0)
    assert len(state.sites) == 5
    assert len(state.jobs) > 0
    assert all(isinstance(a, Action) for a in actions)
