"""The §Perf optimization paths: capacity MoE numerics, sharding
strategies, shape-aware constraint pruning, decode partial-softmax flag."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import moe as moe_lib
from repro.parallel.sharding import DEFAULT_RULES, force_mesh_axes, logical_spec
from repro.parallel.strategies import STRATEGIES, get_strategy


def test_capacity_moe_matches_dense_at_ample_capacity():
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 32, 64, 8, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y_dense, aux_d = moe_lib.apply_moe(p, x, top_k=2, act="silu", impl="dense")
    y_cap, aux_c = moe_lib.apply_moe_capacity(
        p, x, top_k=2, act="silu", capacity_factor=32.0, block=16
    )
    np.testing.assert_allclose(np.asarray(y_dense), np.asarray(y_cap), atol=1e-6)
    np.testing.assert_allclose(float(aux_d), float(aux_c), rtol=1e-6)


def test_capacity_moe_drops_are_bounded():
    """At cf=1.5 only a minority of outputs are affected by capacity drops
    (Switch-style), and dropped-token outputs shrink, never explode."""
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 32, 64, 8, 4, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, 32))
    y_dense, _ = moe_lib.apply_moe(p, x, top_k=2, act="silu", impl="dense")
    y_cap, _ = moe_lib.apply_moe_capacity(p, x, top_k=2, act="silu",
                                          capacity_factor=1.5, block=16)
    touched = float(jnp.mean(jnp.any(jnp.abs(y_dense - y_cap) > 1e-6, axis=-1)))
    assert touched < 0.5
    assert float(jnp.max(jnp.abs(y_cap))) <= float(jnp.max(jnp.abs(y_dense))) * 2 + 1.0


def test_moe_env_dispatch(monkeypatch):
    p = moe_lib.init_moe(jax.random.PRNGKey(0), 16, 32, 4, 2, jnp.float32)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, 16))
    monkeypatch.setenv("REPRO_MOE_IMPL", "capacity")
    y_env, _ = moe_lib.apply_moe(p, x, top_k=2, act="silu")
    y_cap, _ = moe_lib.apply_moe_capacity(p, x, top_k=2, act="silu")
    np.testing.assert_array_equal(np.asarray(y_env), np.asarray(y_cap))


def test_all_strategies_resolve():
    for name in ("baseline", "tp-ffn", "small-repl", "decode-tp", "moe-blocked", "seq-data"):
        assert name in STRATEGIES
        r = get_strategy(name)
        assert r.get("batch") is not None or name in ("seq-data",)
    with pytest.raises(KeyError):
        get_strategy("nope")


def test_decode_tp_strategy_avoids_weight_movement_axes():
    r = get_strategy("decode-tp")
    assert r.get("embed") is None  # d_model dims replicated
    assert r.get("mlp") == "model"  # FFN column-parallel
    assert r.get("head_dim") == "model"  # always divisible (128/16)


def test_logical_spec_dedup_and_unconstrained():
    from jax.sharding import PartitionSpec as P

    with force_mesh_axes(("data", "model")):
        # 'seq' and 'mlp_act' both -> model under tp-ffn-like overrides:
        from repro.parallel.sharding import use_rules

        with use_rules(DEFAULT_RULES.with_overrides(mlp_act="model")):
            spec = logical_spec("batch", "seq", "mlp_act")
        assert spec == P("data", "model", None)  # first claim wins
        spec2 = logical_spec("*", "seq")
        assert spec2[0] is P.UNCONSTRAINED


def test_shd_shape_aware_pruning():
    """A size-1 dim must never claim a mesh axis (the decode bug that caused
    full-weight gathers — EXPERIMENTS.md §Perf decode-tp)."""
    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("model",))
    from repro.parallel.sharding import shd, use_rules

    with mesh, use_rules(DEFAULT_RULES.with_overrides(seq="model", mlp_act="model")):
        x = jnp.ones((2, 1, 8))
        y = shd(x, "batch", "seq", "mlp_act")  # seq dim=1: 'model' must go to mlp_act
        assert y.shape == x.shape


def test_decode_sharded_softmax_flag_numerics(monkeypatch):
    """REPRO_DECODE_SHARDED only adds sharding constraints — never changes
    the math (single-device check)."""
    from repro.configs import get_config
    from repro.models import build_model

    cfg = get_config("qwen3-1.7b").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    cache = model.init_cache(2, 8)
    batch = {"token": jnp.array([1, 2], jnp.int32), "index": jnp.int32(0)}
    outs = {}
    for flag in ("0", "1"):
        monkeypatch.setenv("REPRO_DECODE_SHARDED", flag)
        logits, _ = model.decode_step(params, cache, dict(batch))
        outs[flag] = np.asarray(logits)
    np.testing.assert_allclose(outs["0"], outs["1"], atol=1e-6)
