"""WanTopology: exact reduction to the legacy uniform share model,
per-link caps, asymmetric NICs, brownout calendars, builder validation,
the sharing="waterfill" max-min mode (conservation, dominance over the
conservative split, exact reduction on single-bottleneck flow sets), and
hypothesis properties (shared rates never oversubscribe any NIC/link and
conserve the flow count)."""
import dataclasses

import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: deterministic tests still run
    HAS_HYPOTHESIS = False

from repro.core.state import advertised_bandwidth
from repro.core.wan import (
    WanProfile, WanTopology, hub_spoke_links, partitioned_links,
)

GBPS = 1e9


def test_uniform_reduces_to_legacy_share_model():
    topo = WanTopology.uniform(4, 10 * GBPS)
    flows = [(0, 2), (0, 3), (1, 3), (0, 2)]
    rates = topo.shared_rates(flows)
    # min(nic/src_flows, nic/dst_flows): site0 has 3 outgoing flows
    assert rates[0] == pytest.approx(10 * GBPS / 3)  # 0->2: src 3, dst 2
    assert rates[1] == pytest.approx(10 * GBPS / 3)  # 0->3: src 3, dst 2
    assert rates[2] == pytest.approx(10 * GBPS / 2)  # 1->3: src 1, dst 2
    legacy = advertised_bandwidth(4, 10 * GBPS, flows)
    np.testing.assert_allclose(topo.advertised_matrix(0.0, flows), legacy)


def test_advertised_matrix_no_flows_is_capacity():
    topo = WanTopology.uniform(3, 10 * GBPS)
    np.testing.assert_allclose(topo.advertised_matrix(0.0, ()),
                               np.full((3, 3), 10 * GBPS))


def test_asymmetric_uplink_binds_on_egress():
    prof = WanProfile(gbps=10.0, nic_gbps=(2.5, 2.5), nic_in_gbps=(10.0, 10.0))
    topo = prof.build_topology(2, days=1, seed=0)
    assert topo.capacity(0, 1, 0.0) == pytest.approx(2.5 * GBPS)
    # two concurrent flows out of site 0 halve the *egress* NIC
    rates = topo.shared_rates([(0, 1), (0, 1)])
    np.testing.assert_allclose(rates, 1.25 * GBPS)


def test_link_cap_binds_below_nics():
    prof = WanProfile(gbps=10.0, link_gbps=((None, 1.0), (1.0, None)))
    topo = prof.build_topology(2, days=1, seed=0)
    assert topo.capacity(0, 1, 0.0) == pytest.approx(1 * GBPS)
    # the link, not the NIC, is shared by two flows on the same pair
    rates = topo.shared_rates([(0, 1), (0, 1)])
    np.testing.assert_allclose(rates, 0.5 * GBPS)


def test_zero_capacity_link_gives_zero_rate():
    prof = WanProfile(gbps=10.0, link_gbps=((None, 0.0), (0.0, None)))
    topo = prof.build_topology(2, days=1, seed=0)
    assert topo.capacity(0, 1, 0.0) == 0.0
    assert topo.shared_rates([(0, 1)])[0] == 0.0
    assert topo.advertised_matrix(0.0, ())[0, 1] == 0.0


def test_hub_spoke_and_partitioned_builders():
    links = hub_spoke_links(4, hub=0, spoke_gbps=1.0)
    assert links[0][2] is None and links[2][0] is None  # hub-adjacent
    assert links[1][2] == 1.0 and links[3][1] == 1.0  # spoke-spoke capped
    links = partitioned_links(((0, 1), (2, 3)), inter_gbps=0.25)
    assert links[0][1] is None and links[2][3] is None  # intra
    assert links[0][2] == 0.25 and links[3][1] == 0.25  # inter
    with pytest.raises(ValueError, match="partition"):
        partitioned_links(((0, 1), (1, 2)))


def test_fabric_brownout_matches_legacy_calendar():
    days, seed, prob = 3, 5, 0.4
    prof = WanProfile(gbps=10.0, hourly_degrade_prob=prob, degraded_gbps=0.5)
    topo = prof.build_topology(4, days=days, seed=seed)
    n_hours = days * 48 + 1
    legacy_bad = np.random.default_rng(seed + 31).random(n_hours) < prob
    for h in range(days * 24):
        want = 0.5 * GBPS if legacy_bad[h] else 10 * GBPS
        assert topo.nic_bps_at(h * 3600.0 + 10.0) == pytest.approx(want)


def test_per_link_brownout_degrades_only_affected_links():
    prof = WanProfile(gbps=10.0, hourly_degrade_prob=0.5, degraded_gbps=0.5,
                      brownout_scope="per-link")
    topo = prof.build_topology(5, days=3, seed=0)
    mask = topo.brownout_mask
    assert mask.ndim == 3
    h = next(h for h in range(len(mask)) if mask[h].any() and not mask[h].all())
    t = h * 3600.0 + 1.0
    cap = topo.capacity_matrix(t)
    bad = mask[h]
    assert (cap[bad] == 0.5 * GBPS).all()
    assert (cap[~bad & ~np.eye(5, dtype=bool)] == 10 * GBPS).all()


def test_next_transition_walks_brownout_edges():
    prof = WanProfile(gbps=10.0, hourly_degrade_prob=0.5)
    topo = prof.build_topology(3, days=3, seed=1)
    t = 0.0
    seen = 0
    while True:
        nxt = topo.next_transition(t)
        if not np.isfinite(nxt):
            break
        assert nxt > t
        assert nxt % 3600.0 == 0.0  # hourly calendar
        # the state really changes across the edge
        assert (topo.nic_bps_at(nxt - 1.0) != topo.nic_bps_at(nxt + 1.0))
        t = nxt
        seen += 1
    assert seen > 0


def test_no_brownouts_never_transitions():
    topo = WanTopology.uniform(3, 10 * GBPS)
    assert topo.next_transition(0.0) == float("inf")


def test_profile_validation():
    with pytest.raises(ValueError, match="nic_gbps"):
        WanProfile(nic_gbps=(1.0, 2.0)).build_topology(3, days=1, seed=0)
    with pytest.raises(ValueError, match="matrix"):
        WanProfile(link_gbps=((None,),)).build_topology(2, days=1, seed=0)
    with pytest.raises(ValueError, match="brownout_scope"):
        WanProfile(hourly_degrade_prob=0.5,
                   brownout_scope="chaos").build_topology(2, days=1, seed=0)


# ---------------------------------------------------------------------------
# sharing="waterfill": full max-min water-filling
# ---------------------------------------------------------------------------


def waterfill_of(topo: WanTopology) -> WanTopology:
    return dataclasses.replace(topo, sharing="waterfill")


def test_waterfill_redistributes_residual_of_frozen_bottlenecks():
    """The textbook case the conservative split leaves on the table: three
    flows saturate out0 at 10/3 each, which leaves in1 half idle — under
    max-min the fourth flow (4->1) inherits the residual (6.67 Gbps) where
    the conservative model grants only min(10/1, 10/2) = 5."""
    topo = WanTopology.uniform(5, 10 * GBPS)
    wf = waterfill_of(topo)
    flows = [(0, 1), (0, 2), (0, 3), (4, 1)]
    cons = topo.shared_rates(flows)
    rates = wf.shared_rates(flows)
    np.testing.assert_allclose(rates[:3], 10 * GBPS / 3)
    assert cons[3] == pytest.approx(5 * GBPS)
    assert rates[3] == pytest.approx(10 * GBPS - 10 * GBPS / 3)  # 6.67


def test_waterfill_reduces_exactly_on_single_bottleneck_flow_sets():
    """Exact-reduction caveat: when every flow is frozen by the same first
    saturating resource (all flows out of one site on a uniform fabric),
    waterfill IS the conservative split."""
    topo = WanTopology.uniform(4, 10 * GBPS)
    wf = waterfill_of(topo)
    for flows in ([(0, 1)], [(0, 1), (0, 2)], [(0, 1), (0, 2), (0, 2)],
                  [(0, 3), (0, 3), (0, 3)]):
        np.testing.assert_allclose(wf.shared_rates(flows),
                                   topo.shared_rates(flows))


def test_waterfill_zero_capacity_and_link_caps():
    prof = WanProfile(gbps=10.0, link_gbps=((None, 0.0), (1.0, None)),
                      sharing="waterfill")
    topo = prof.build_topology(2, days=1, seed=0)
    assert topo.shared_rates([(0, 1)])[0] == 0.0
    # the 1 Gbps link binds below the NICs and is split two ways
    np.testing.assert_allclose(topo.shared_rates([(1, 0), (1, 0)]),
                               0.5 * GBPS)


def test_waterfill_advertised_matrix_consistent_with_rates():
    topo = waterfill_of(WanTopology.uniform(5, 10 * GBPS))
    flows = [(0, 1), (0, 2), (0, 3), (4, 1)]
    rates = topo.shared_rates(flows)
    adv = topo.advertised_matrix(0.0, flows)
    for (s, d), r in zip(flows, rates):
        assert adv[s, d] == pytest.approx(r)
    # idle pairs advertise the post-admission water-fill of a new flow —
    # never more than uncontended capacity, never negative
    assert (adv <= topo.capacity_matrix(0.0) + 1e-6).all()
    assert (adv >= 0.0).all()
    # a new flow into the saturated in1 would get in1's residual share
    assert adv[2, 1] == pytest.approx(
        topo.post_admission_rate(2, 1, flows))


def test_waterfill_profile_and_validation():
    prof = WanProfile(gbps=10.0, sharing="waterfill")
    assert prof.build_topology(3, days=1, seed=0).sharing == "waterfill"
    with pytest.raises(ValueError, match="sharing"):
        WanProfile(sharing="greedy").build_topology(2, days=1, seed=0)


# ---------------------------------------------------------------------------
# Property tests: conservation under arbitrary topologies + flow sets
# ---------------------------------------------------------------------------

if HAS_HYPOTHESIS:

    @st.composite
    def topology_and_flows(draw):
        n = draw(st.integers(2, 6))
        gbps = st.floats(0.1, 100.0)
        out = tuple(draw(gbps) for _ in range(n))
        in_ = tuple(draw(gbps) for _ in range(n))
        link = tuple(
            tuple(draw(st.one_of(st.none(), st.floats(0.0, 50.0)))
                  for _ in range(n))
            for _ in range(n))
        prob = draw(st.sampled_from([0.0, 0.5]))
        scope = draw(st.sampled_from(["fabric", "per-link"]))
        prof = WanProfile(nic_gbps=out, nic_in_gbps=in_, link_gbps=link,
                          hourly_degrade_prob=prob, degraded_gbps=0.5,
                          brownout_scope=scope)
        topo = prof.build_topology(n, days=2, seed=draw(st.integers(0, 5)))
        pairs = [(s, d) for s in range(n) for d in range(n) if s != d]
        flows = draw(st.lists(st.sampled_from(pairs), min_size=0, max_size=12))
        t = draw(st.floats(0.0, 2 * 24 * 3600.0))
        return topo, flows, t

    @given(topology_and_flows())
    @settings(max_examples=80, deadline=None)
    def test_shared_rates_conserve_capacity_and_flow_count(tf):
        topo, flows, t = tf
        rates = topo.shared_rates(flows, t)
        # conserves the flow count: one non-negative rate per flow
        assert len(rates) == len(flows)
        assert (rates >= 0.0).all()
        out, in_, link = topo.resources_at(t)
        tol = 1e-6
        # no flow exceeds its uncontended point-to-point capacity
        for (s, d), r in zip(flows, rates):
            assert r <= topo.capacity(s, d, t) * (1 + tol)
        # aggregate over every NIC and link stays within capacity
        for s in range(topo.n_sites):
            tot = sum(r for (fs, _), r in zip(flows, rates) if fs == s)
            assert tot <= out[s] * (1 + tol)
        for d in range(topo.n_sites):
            tot = sum(r for (_, fd), r in zip(flows, rates) if fd == d)
            assert tot <= in_[d] * (1 + tol)
        for (s, d) in set(flows):
            tot = sum(r for f, r in zip(flows, rates) if f == (s, d))
            assert tot <= link[s, d] * (1 + tol) or np.isinf(link[s, d])

    @given(topology_and_flows())
    @settings(max_examples=50, deadline=None)
    def test_advertised_matrix_agrees_with_shared_rates(tf):
        topo, flows, t = tf
        rates = topo.shared_rates(flows, t)
        adv = topo.advertised_matrix(t, flows)
        for (s, d), r in zip(flows, rates):
            assert adv[s, d] == pytest.approx(r, rel=1e-9, abs=1e-6)

    @given(st.integers(2, 6), st.floats(0.5, 50.0),
           st.lists(st.tuples(st.integers(0, 5), st.integers(0, 5)),
                    min_size=1, max_size=10))
    @settings(max_examples=50, deadline=None)
    def test_uniform_property_matches_legacy(n, gbps, raw_flows):
        flows = [(s % n, d % n) for s, d in raw_flows if s % n != d % n]
        topo = WanTopology.uniform(n, gbps * GBPS)
        np.testing.assert_allclose(
            topo.advertised_matrix(0.0, flows),
            advertised_bandwidth(n, gbps * GBPS, flows))

    @given(topology_and_flows())
    @settings(max_examples=80, deadline=None)
    def test_waterfill_conserves_every_resource_capacity(tf):
        """Waterfill never oversubscribes any NIC or link, on arbitrary
        topologies, brownout states and flow sets."""
        topo, flows, t = tf
        wf = waterfill_of(topo)
        rates = wf.shared_rates(flows, t)
        assert len(rates) == len(flows)
        assert (rates >= 0.0).all()
        out, in_, link = wf.resources_at(t)
        tol = 1e-6
        for s in range(wf.n_sites):
            tot = sum(r for (fs, _), r in zip(flows, rates) if fs == s)
            assert tot <= out[s] * (1 + tol)
        for d in range(wf.n_sites):
            tot = sum(r for (_, fd), r in zip(flows, rates) if fd == d)
            assert tot <= in_[d] * (1 + tol)
        for (s, d) in set(flows):
            tot = sum(r for f, r in zip(flows, rates) if f == (s, d))
            assert tot <= link[s, d] * (1 + tol) or np.isinf(link[s, d])

    @given(topology_and_flows())
    @settings(max_examples=80, deadline=None)
    def test_waterfill_dominates_conservative_per_flow(tf):
        """Every flow's water-filled rate is >= its conservative single-round
        split — the residual is only ever redistributed, never taken."""
        topo, flows, t = tf
        if not flows:
            return
        cons = topo.shared_rates(flows, t)
        rates = waterfill_of(topo).shared_rates(flows, t)
        assert (rates >= cons * (1 - 1e-9) - 1e-6).all()

    @given(st.integers(2, 6), st.floats(0.5, 50.0),
           st.lists(st.integers(0, 5), min_size=1, max_size=8))
    @settings(max_examples=50, deadline=None)
    def test_waterfill_reduces_to_conservative_on_uniform_single_source(
            n, gbps, raw_dsts):
        """Exact-reduction property on uniform fabrics: with every flow
        leaving one source NIC, the first water-filling round freezes all
        of them at nic/k — identically the conservative split.  (With
        several disjoint bottlenecks waterfill strictly dominates; see
        test_waterfill_redistributes_residual_of_frozen_bottlenecks.)"""
        src = 0
        flows = [(src, 1 + d % (n - 1)) for d in raw_dsts]
        topo = WanTopology.uniform(n, gbps * GBPS)
        np.testing.assert_allclose(
            waterfill_of(topo).shared_rates(flows),
            topo.shared_rates(flows))


# ---------------------------------------------------------------------------
# multi-hop relaying
# ---------------------------------------------------------------------------

def hub_spoke_topo(multi_hop=True, sharing="conservative"):
    prof = WanProfile(gbps=10.0,
                      nic_gbps=(40.0, 10.0, 10.0, 10.0, 10.0),
                      link_gbps=hub_spoke_links(5, hub=0, spoke_gbps=1.0),
                      sharing=sharing, multi_hop=multi_hop)
    return prof.build_topology(5, days=1, seed=0)


def test_multi_hop_relays_spokes_through_hub():
    topo = hub_spoke_topo()
    r = topo.relay
    assert r is not None
    # every spoke pair relays through the hub; hub-adjacent pairs stay direct
    for s in range(1, 5):
        for d in range(1, 5):
            if s != d:
                assert r[s, d] == 0
        assert r[0, s] == -1 and r[s, 0] == -1
    assert topo.capacity(1, 2, 0.0) == pytest.approx(10 * GBPS)
    assert topo.reachable(1, 2)
    cm = np.asarray(topo.capacity_matrix(0.0))
    assert cm[1, 2] == pytest.approx(10 * GBPS)
    assert cm[0, 1] == pytest.approx(10 * GBPS)  # direct, spoke NIC bound


def test_multi_hop_off_keeps_direct_caps():
    topo = hub_spoke_topo(multi_hop=False)
    assert topo.relay is None
    assert topo.capacity(1, 2, 0.0) == pytest.approx(1 * GBPS)
    assert np.asarray(topo.capacity_matrix(0.0))[1, 2] == pytest.approx(1 * GBPS)


def test_multi_hop_keeps_direct_when_not_strictly_better():
    # uniform fabric: relaying never beats the direct NIC-bound path
    prof = WanProfile(gbps=10.0, multi_hop=True)
    topo = prof.build_topology(4, days=1, seed=0)
    assert (topo.relay == -1).all()
    rates = topo.shared_rates([(0, 2), (0, 3), (1, 3)])
    ref = WanProfile(gbps=10.0).build_topology(4, days=1, seed=0)
    np.testing.assert_allclose(rates, ref.shared_rates([(0, 2), (0, 3), (1, 3)]))


@pytest.mark.parametrize("sharing", ["conservative", "waterfill"])
def test_multi_hop_capacity_conservation(sharing):
    """Per-leg accounting: summing each relayed flow's rate over every NIC
    and link on its path never oversubscribes any resource."""
    topo = hub_spoke_topo(sharing=sharing)
    flows = [(1, 2), (1, 3), (2, 4), (3, 4), (0, 1), (4, 0)]
    rates = topo.shared_rates(flows, 0.0)
    assert (np.asarray(rates) > 0).all()
    out, in_, link = topo.resources_at(0.0)
    tol = 1e-6
    use_out = np.zeros(5)
    use_in = np.zeros(5)
    use_link = np.zeros((5, 5))
    for (s, d), r in zip(flows, rates):
        for a, b in topo._path(s, d):
            use_out[a] += r
            use_in[b] += r
            use_link[a, b] += r
    assert (use_out <= out * (1 + tol)).all()
    assert (use_in <= in_ * (1 + tol)).all()
    finite = np.isfinite(link)
    assert (use_link[finite] <= link[finite] * (1 + tol)).all()


def test_multi_hop_hub_nic_contention():
    """Four relayed spoke flows all traverse the hub: each leg consumes the
    hub's 40 Gbps NICs, so the per-flow grant reflects the extra hops."""
    topo = hub_spoke_topo()
    flows = [(1, 2), (1, 2)]  # two flows on the same relayed pair
    rates = topo.shared_rates(flows, 0.0)
    # both share site 1's 10 Gbps egress NIC on the first leg
    np.testing.assert_allclose(rates, 5 * GBPS)
