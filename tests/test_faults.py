"""Fault-injection + recovery subsystem (core/faults.py).

Covers the PR-9 spine end to end: deterministic FaultPlan realization,
the faults-off byte-identity gate, the transfer-stall watchdog (the fix
for the historic silent-infinite-stall bug — active with no FaultRegime
at all), blackout rollback + telemetry, the fixed-dt rejection contract,
serving replica crashes, randomized no-job-lost / ledger-audit property
sweeps over arbitrary fault plans, and the 8-seed blackout-cascade
acceptance comparison (fault-aware + retry vs the fault-blind baseline).
"""
import dataclasses
import math

import numpy as np
import pytest

from repro.core.faults import FaultPlan, FaultRegime, RetryPolicy
from repro.core.orchestrator import make_policy
from repro.core.scenarios import get_scenario
from repro.core.simulator import ClusterSimulator, SimConfig

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container may not ship hypothesis: the seeded
    HAVE_HYPOTHESIS = False  # randomized sweep below still runs


# ---------------------------------------------------------------------------
# retry ladder
# ---------------------------------------------------------------------------

class TestRetryPolicy:
    def test_backoff_ladder(self):
        rp = RetryPolicy(max_attempts=3, backoff_base_s=600.0,
                         backoff_mult=2.0)
        assert rp.backoff_s(1) == 600.0
        assert rp.backoff_s(2) == 1200.0
        assert rp.backoff_s(3) == 2400.0

    def test_first_attempt_uses_base(self):
        rp = RetryPolicy(backoff_base_s=100.0, backoff_mult=3.0)
        assert rp.backoff_s(0) == 100.0  # clamped, never mult**-1
        assert rp.backoff_s(1) == 100.0


# ---------------------------------------------------------------------------
# FaultPlan realization
# ---------------------------------------------------------------------------

REGIME_ALL = FaultRegime(
    site_blackout_rate_per_day=1.0, site_blackout_mean_s=3600.0,
    link_failure_rate_per_day=1.5, link_failure_mean_s=1800.0,
    ckpt_corruption_prob=0.2,
    replica_crash_rate_per_day=1.0, replica_crash_mean_s=1200.0,
    straggler_rate_per_day=1.0, straggler_mean_s=3600.0,
    straggler_factor=0.5)

DAY = 24 * 3600.0


class TestFaultPlan:
    def test_deterministic(self):
        a = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=7)
        b = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=7)
        for x, y in zip(a.site_spans, b.site_spans):
            np.testing.assert_array_equal(x, y)
        assert set(a.link_spans) == set(b.link_spans)
        for k in a.link_spans:
            np.testing.assert_array_equal(a.link_spans[k], b.link_spans[k])
        np.testing.assert_array_equal(a.edges, b.edges)

    def test_seed_sensitivity(self):
        a = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=7)
        b = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=8)
        assert not np.array_equal(a.edges, b.edges)

    def test_per_class_stream_independence(self):
        """Adding a fault class never reshuffles another's spans."""
        solo = FaultRegime(site_blackout_rate_per_day=1.0,
                           site_blackout_mean_s=3600.0)
        a = FaultPlan.build(solo, 5, 3 * DAY, seed=7)
        b = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=7)
        for x, y in zip(a.site_spans, b.site_spans):
            np.testing.assert_array_equal(x, y)

    def test_spans_sorted_nonoverlapping(self):
        plan = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=3)
        all_spans = (list(plan.site_spans) + list(plan.link_spans.values())
                     + list(plan.replica_spans)
                     + list(plan.straggler_spans))
        for sp in all_spans:
            if not len(sp):
                continue
            assert (sp[:, 1] > sp[:, 0]).all()
            assert (sp[1:, 0] >= sp[:-1, 1]).all()

    def test_queries_consistent_with_spans(self):
        plan = FaultPlan.build(REGIME_ALL, 4, 2 * DAY, seed=11)
        for s in range(4):
            for start, end in plan.site_spans[s]:
                assert not plan.site_up(s, start)  # half-open [start, end)
                assert plan.site_up(s, end)
                # absolute repair instant, not a duration
                assert plan.repair_time_s(s, start) == pytest.approx(end)
        up = plan.site_up_vec(0.0)
        assert up.shape == (4,) and up.dtype == bool

    def test_link_up_composes_site_blackouts(self):
        """A blacked-out site darkens every link touching it."""
        plan = FaultPlan.build(REGIME_ALL, 4, 2 * DAY, seed=11)
        for s in range(4):
            if not len(plan.site_spans[s]):
                continue
            t = float(plan.site_spans[s][0, 0])
            mat = plan.link_up_mat(t)
            off = [i for i in range(4) if i != s]  # diagonal stays True
            assert not mat[s, off].any()
            assert not mat[off, s].any()

    def test_outage_stats(self):
        plan = FaultPlan.build(REGIME_ALL, 5, 3 * DAY, seed=7)
        n, mttr = plan.outage_stats(3 * DAY)
        total = sum(len(sp[sp[:, 0] < 3 * DAY]) for sp in plan.site_spans)
        assert n == total
        if n:
            assert mttr > 0.0

    def test_all_off_regime_inactive(self):
        assert not FaultRegime().any_active()
        assert REGIME_ALL.any_active()
        assert FaultRegime(job_failure_rate_per_slot_hour=0.1).any_active()


# ---------------------------------------------------------------------------
# faults-off identity: an all-off regime is byte-identical to None
# ---------------------------------------------------------------------------

class TestFaultsOffIdentity:
    def test_all_off_regime_matches_none(self):
        cfg = dict(n_sites=4, n_jobs=24, days=2, seed=5)
        r_none = ClusterSimulator(SimConfig(faults=None, **cfg),
                                  make_policy("receding-horizon")).run()
        r_off = ClusterSimulator(SimConfig(faults=FaultRegime(), **cfg),
                                 make_policy("receding-horizon")).run()
        a, b = r_none.summary(), r_off.summary()
        for d in (a, b):  # wall-clock keys are nondeterministic
            for k in ("wall_time_s", "wall_s", "decide_s",
                      "decide_first_s", "ticks_per_sec", "events_per_sec"):
                d.pop(k, None)
        assert a == b

    def test_fault_plan_not_built_when_inactive(self):
        sim = ClusterSimulator(SimConfig(faults=FaultRegime(), n_jobs=4),
                               make_policy("static"))
        assert sim.fault_plan is None


# ---------------------------------------------------------------------------
# transfer-stall watchdog (satellite 1: the historic silent-stall bug)
# ---------------------------------------------------------------------------

STALL_CFG = dict(n_sites=4, n_jobs=16, days=2, mean_compute_h=6.0,
                 wan_gbps=1.0, wan_degrade_prob=1.0,
                 wan_degraded_gbps=0.0, seed=3)


class TestStallWatchdog:
    """A permanently-zero brownout calendar reproduces the pre-PR bug: a
    migration admitted on a link whose shared rate is 0 strands the job
    in ``migrating`` forever.  The watchdog (no FaultRegime involved)
    aborts the dead transfer, requeues at the source and walks the
    bounded-retry ladder."""

    def test_without_watchdog_jobs_strand_forever(self):
        r = ClusterSimulator(
            SimConfig(stall_timeout_s=float("inf"), **STALL_CFG),
            make_policy("energy-only")).run()
        stuck = [j for j in r.jobs if j.state == "migrating"]
        assert stuck, "expected stranded transfers with the watchdog off"
        assert r.completed < STALL_CFG["n_jobs"]

    def test_watchdog_rescues_every_job(self):
        r = ClusterSimulator(
            SimConfig(stall_timeout_s=900.0, **STALL_CFG),
            make_policy("energy-only")).run()
        assert r.watchdog_aborts > 0
        assert r.retries > 0
        assert not any(j.state == "migrating" for j in r.jobs)
        assert r.completed == STALL_CFG["n_jobs"]
        # every abort is a failed migration, counted exactly once
        assert r.failed_migrations >= r.watchdog_aborts

    def test_watchdog_independent_of_fault_regime(self):
        sim = ClusterSimulator(
            SimConfig(stall_timeout_s=900.0, **STALL_CFG),
            make_policy("energy-only"))
        assert sim.fault_plan is None  # no FaultRegime anywhere
        r = sim.run()
        assert r.watchdog_aborts > 0


# ---------------------------------------------------------------------------
# blackout rollback + telemetry spine
# ---------------------------------------------------------------------------

class TestBlackoutRecovery:
    def test_cascade_telemetry_and_audits(self):
        scn = get_scenario("blackout-cascade")
        sim = ClusterSimulator.from_scenario(
            scn, make_policy("receding-horizon"),
            overrides=dict(days=2, n_jobs=16, mean_compute_h=20.0, seed=0))
        r = sim.run()  # _result() runs audit_no_job_lost under chaos
        sim.ledger.audit()
        assert r.site_outages > 0
        assert r.mttr_s > 0.0
        assert r.completed > 0
        s = r.summary()
        for key in ("site_outages", "mttr_s", "retries", "reroutes",
                    "replica_crashes", "watchdog_aborts"):
            assert key in s

    def test_forecast_carries_fault_plan(self):
        scn = get_scenario("blackout-cascade")
        sim = ClusterSimulator.from_scenario(
            scn, make_policy("receding-horizon"),
            overrides=dict(days=2, n_jobs=8, seed=0))
        fc = sim.forecast_horizon
        assert fc.faults is sim.fault_plan
        plan = sim.fault_plan
        # repair estimate (absolute instant) matches the plan mid-outage
        for s in range(sim.cfg.n_sites):
            if len(plan.site_spans[s]):
                t0, t1 = plan.site_spans[s][0]
                assert fc.site_repair_s(int(s), float(t0)) == pytest.approx(t1)
                break
        # next-fault queries clip to the forecast horizon
        far = 2.0 * sim.cfg.days * 24 * 3600.0
        assert fc.next_fault_start_after(0, 1, far) == float("inf")

    def test_prebuilt_horizon_gets_plan_grafted(self):
        """Sweep cells share horizons built without faults; the sim must
        graft its plan on (identical calendar, same seed)."""
        from repro.core.sweep import SweepSpec, run_sweep
        spec = SweepSpec(scenarios=["blackout-cascade"],
                         policies=["receding-horizon"], seeds=[0],
                         overrides=dict(days=1, n_jobs=6))
        res = run_sweep(spec, workers=1)
        agg = res.aggregate()[("blackout-cascade", "receding-horizon")]
        assert agg["site_outages"]["mean"] >= 0.0  # telemetry flowed


# ---------------------------------------------------------------------------
# engine contract: fixed-dt refuses fault regimes
# ---------------------------------------------------------------------------

class TestFixedDtRejectsFaults:
    def test_raises_with_clear_error(self):
        cfg = SimConfig(engine="fixed-dt", n_jobs=4,
                        faults=FaultRegime(site_blackout_rate_per_day=1.0))
        sim = ClusterSimulator(cfg, make_policy("static"))
        with pytest.raises(ValueError, match="fault injection.*event"):
            sim.run()

    def test_even_all_off_regime_rejected(self):
        """The contract is on the config, not the realized plan: carrying
        any FaultRegime into fixed-dt is a spec error."""
        cfg = SimConfig(engine="fixed-dt", n_jobs=4, faults=FaultRegime())
        sim = ClusterSimulator(cfg, make_policy("static"))
        with pytest.raises(ValueError, match="fault injection"):
            sim.run()


# ---------------------------------------------------------------------------
# serving replica crashes
# ---------------------------------------------------------------------------

class TestReplicaCrashes:
    def test_requests_conserved_under_crashes(self):
        scn = get_scenario("inference-diurnal").replace(
            faults=FaultRegime(replica_crash_rate_per_day=4.0,
                               replica_crash_mean_s=3600.0))
        r = ClusterSimulator.from_scenario(
            scn, make_policy("receding-horizon"),
            overrides=dict(days=1, n_jobs=8, seed=1)).run()
        assert r.replica_crashes > 0
        # crashes re-drain queues and re-route in-flight batches; no
        # request ever leaves the system
        assert r.requests_arrived == r.requests_served + r.requests_dropped


# ---------------------------------------------------------------------------
# invariants under chaos: randomized fault plans
# ---------------------------------------------------------------------------

def _run_chaos(regime: FaultRegime, seed: int, policy: str):
    cfg = SimConfig(n_sites=4, n_jobs=10, days=1, mean_compute_h=4.0,
                    seed=seed, faults=regime)
    sim = ClusterSimulator(cfg, make_policy(policy))
    r = sim.run()  # audit_no_job_lost runs inside _result
    sim.ledger.audit()
    states = {}
    for j in r.jobs:
        states[j.state] = states.get(j.state, 0) + 1
    assert sum(states.values()) == cfg.n_jobs, states
    assert states.get("done", 0) == r.completed
    return r


def _random_regime(rng: np.random.Generator) -> FaultRegime:
    return FaultRegime(
        site_blackout_rate_per_day=float(rng.uniform(0.0, 3.0)),
        site_blackout_mean_s=float(rng.uniform(600.0, 6 * 3600.0)),
        link_failure_rate_per_day=float(rng.uniform(0.0, 4.0)),
        link_failure_mean_s=float(rng.uniform(600.0, 8 * 3600.0)),
        ckpt_corruption_prob=float(rng.uniform(0.0, 0.5)),
        straggler_rate_per_day=float(rng.uniform(0.0, 2.0)),
        straggler_factor=float(rng.uniform(0.2, 0.9)),
        job_failure_rate_per_slot_hour=float(rng.uniform(0.0, 0.05)),
        stall_timeout_s=float(rng.uniform(600.0, 7200.0)),
        retry=RetryPolicy(max_attempts=int(rng.integers(1, 4)),
                          backoff_base_s=float(rng.uniform(300.0, 3600.0))))


class TestChaosInvariants:
    """No-job-lost + ledger audits hold for arbitrary fault sequences."""

    def test_randomized_fault_plans(self):
        rng = np.random.default_rng(2026)
        for i in range(8):
            regime = _random_regime(rng)
            policy = ("receding-horizon", "feasibility-aware",
                      "energy-only", "plan-ahead")[i % 4]
            _run_chaos(regime, seed=i, policy=policy)

    def test_fault_blind_arms_hold_invariants_too(self):
        regime = dataclasses.replace(
            REGIME_ALL, stall_timeout_s=float("inf"))
        cfg = SimConfig(n_sites=4, n_jobs=10, days=1, mean_compute_h=4.0,
                        seed=3, faults=regime)
        sim = ClusterSimulator(
            cfg, make_policy("receding-horizon", fault_aware=False))
        sim.run()
        sim.ledger.audit()


if HAVE_HYPOTHESIS:
    @settings(max_examples=10, deadline=None)
    @given(blackout=st.floats(0.0, 3.0), link=st.floats(0.0, 4.0),
           corrupt=st.floats(0.0, 0.5), seed=st.integers(0, 31))
    def test_no_job_lost_property(blackout, link, corrupt, seed):
        regime = FaultRegime(site_blackout_rate_per_day=blackout,
                             site_blackout_mean_s=3600.0,
                             link_failure_rate_per_day=link,
                             link_failure_mean_s=3600.0,
                             ckpt_corruption_prob=corrupt)
        _run_chaos(regime, seed=seed, policy="receding-horizon")


# ---------------------------------------------------------------------------
# acceptance: fault-aware + retry beats the fault-blind baseline
# ---------------------------------------------------------------------------

class TestBlackoutCascadeAcceptance:
    """8-seed sweep on blackout-cascade: fault-aware receding-horizon
    with the retry ladder vs the fault-blind baseline (pre-PR behavior:
    no masking, no watchdog — dead-link transfers stall silently).  The
    aware arm must post higher completions AND lower failed-migrations
    with non-overlapping 95% CIs."""

    SEEDS = range(8)
    OVERRIDES = dict(days=3, n_jobs=24, mean_compute_h=85.0)

    def _sweep(self, scn, **pol_kw):
        comp, failed = [], []
        for seed in self.SEEDS:
            r = ClusterSimulator.from_scenario(
                scn, make_policy("receding-horizon", **pol_kw),
                overrides=dict(seed=seed, **self.OVERRIDES)).run()
            comp.append(r.completed)
            failed.append(r.failed_migrations)
        return np.asarray(comp, float), np.asarray(failed, float)

    @staticmethod
    def _ci95(x: np.ndarray) -> float:
        return 1.96 * x.std() / math.sqrt(len(x))

    def test_aware_beats_blind_with_separated_cis(self):
        scn = get_scenario("blackout-cascade")
        blind_scn = scn.replace(faults=dataclasses.replace(
            scn.faults, stall_timeout_s=float("inf")))
        c_aware, f_aware = self._sweep(scn)
        c_blind, f_blind = self._sweep(blind_scn, fault_aware=False)
        # completions: aware's lower CI edge above blind's upper edge
        assert (c_aware.mean() - self._ci95(c_aware)
                > c_blind.mean() + self._ci95(c_blind)), (
            c_aware.tolist(), c_blind.tolist())
        # failed migrations (stranded dead-link transfers): aware's
        # upper edge below blind's lower edge
        assert (f_aware.mean() + self._ci95(f_aware)
                < f_blind.mean() - self._ci95(f_blind)), (
            f_aware.tolist(), f_blind.tolist())
