"""Grid-signals subsystem: SignalStack analytic integrals, the
carbon/price accounting invariants, the demand-response events, the
signal-aware ForecastHorizon queries, and the receding-horizon policy's
acceptance bar (strictly lower mean gCO2 than plan-ahead on carbon-peaks
at no completion cost)."""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: deterministic tests still run
    HAS_HYPOTHESIS = False

from repro.core import ClusterSimulator, get_scenario
from repro.core.forecast import ForecastHorizon, WindowForecast
from repro.core.signals import (
    CurtailRequest, SignalProfile, SignalStack, curtail_requests_from_carbon,
    generate_signals, grid_signal_integral,
)
from repro.core.traces import SiteTrace, Window

HOUR = 3600.0


def make_stack(seed=0, n_sites=3, n_hours=48):
    rng = np.random.default_rng(seed)
    edges = np.arange(n_hours + 1, dtype=float) * HOUR
    values = rng.uniform(50.0, 700.0, (n_sites, n_hours))
    return SignalStack.from_values(edges, values)


def brute_integral(stack, site, t0, t1, dt=1.0):
    """Riemann reference (left rule on a fine grid)."""
    if t1 <= t0:
        return 0.0
    ts = np.arange(t0, t1, dt)
    return sum(stack.value(site, float(t)) * min(dt, t1 - t) for t in ts)


# ---------------------------------------------------------------------------
# SignalStack
# ---------------------------------------------------------------------------


def test_value_and_grid_agree():
    stack = make_stack()
    for t in (0.0, 0.5 * HOUR, HOUR, 23.7 * HOUR, 47.99 * HOUR, 60 * HOUR):
        grid = stack.value_grid(t)
        for s in range(stack.n_sites):
            assert float(grid[s]) == stack.value(s, t)


def test_integral_exact_on_segment_aligned_spans():
    """Piecewise-constant exactness: any breakpoint-aligned span integrates
    to the exact sum of value*width."""
    stack = make_stack(1)
    for s in range(stack.n_sites):
        for a, b in ((0, 5), (3, 20), (10, 48)):
            want = float(stack.values[s, a:b].sum() * HOUR)
            assert stack.integral(s, a * HOUR, b * HOUR) == pytest.approx(
                want, rel=1e-12)


def test_integral_matches_riemann_on_arbitrary_spans():
    stack = make_stack(2)
    rng = np.random.default_rng(7)
    for _ in range(20):
        t0 = float(rng.uniform(0, 47 * HOUR))
        t1 = t0 + float(rng.uniform(0, 5 * HOUR))
        s = int(rng.integers(stack.n_sites))
        got = stack.integral(s, t0, t1)
        # left-rule reference: up to |Δvalue|·dt error per breakpoint
        assert got == pytest.approx(brute_integral(stack, s, t0, t1),
                                    rel=1e-3, abs=5000.0)
    # integral_grid = per-site integrals
    g = stack.integral_grid(3.3 * HOUR, 9.9 * HOUR)
    for s in range(stack.n_sites):
        assert float(g[s]) == pytest.approx(
            stack.integral(s, 3.3 * HOUR, 9.9 * HOUR), rel=1e-12)


def test_constant_extrapolation_beyond_edges():
    stack = make_stack(3, n_hours=4)
    s = 0
    last = stack.value(s, 3.5 * HOUR)
    assert stack.value(s, 100 * HOUR) == last
    # integral across the end: covered part + constant tail
    want = stack.integral(s, 3 * HOUR, 4 * HOUR) + 2 * HOUR * last
    assert stack.integral(s, 3 * HOUR, 6 * HOUR) == pytest.approx(want,
                                                                  rel=1e-12)


def test_grid_signal_integral_subtracts_window_overlaps():
    stack = make_stack(4)
    tr = SiteTrace(0, [Window(2 * HOUR, 5 * HOUR), Window(8 * HOUR, 9 * HOUR)])
    t0, t1 = 1 * HOUR, 10 * HOUR
    got = grid_signal_integral(stack, 0, tr.overlaps(t0, t1), t0, t1)
    want = (stack.integral(0, t0, t1)
            - stack.integral(0, 2 * HOUR, 5 * HOUR)
            - stack.integral(0, 8 * HOUR, 9 * HOUR))
    assert got == pytest.approx(want, rel=1e-12)
    # fully-green span: zero grid integral
    assert grid_signal_integral(
        stack, 0, tr.overlaps(3 * HOUR, 4 * HOUR),
        3 * HOUR, 4 * HOUR) == pytest.approx(0.0, abs=1e-9)


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10_000),
           st.floats(min_value=0.0, max_value=40 * HOUR),
           st.floats(min_value=0.0, max_value=12 * HOUR))
    def test_grid_signal_integral_matches_fixed_dt_hypothesis(seed, t0, dur):
        """The conservation property the issue names: the analytic
        non-renewable signal integral equals fixed-dt integration within
        tolerance on arbitrary spans/windows, and both are exact sums of
        segment contributions for the piecewise-constant traces."""
        rng = np.random.default_rng(seed)
        stack = make_stack(seed, n_sites=1)
        wins, t = [], 0.0
        for _ in range(int(rng.integers(0, 6))):
            gap = float(rng.uniform(0.2, 6.0)) * HOUR
            w = float(rng.uniform(0.2, 4.0)) * HOUR
            wins.append(Window(t + gap, t + gap + w))
            t += gap + w
        tr = SiteTrace(0, wins)
        t1 = t0 + dur
        got = grid_signal_integral(stack, 0, tr.overlaps(t0, t1), t0, t1)
        # fixed-dt Riemann reference over the same grid/green partition
        dt, acc = 30.0, 0.0
        tt = t0
        while tt < t1:
            step = min(dt, t1 - tt)
            if not tr.active(tt):
                acc += stack.value(0, tt) * step
            tt += step
        assert got == pytest.approx(acc, rel=0.02, abs=2 * HOUR)
else:  # pragma: no cover
    @pytest.mark.skip(reason="hypothesis not installed")
    def test_grid_signal_integral_matches_fixed_dt_hypothesis():
        pass


# ---------------------------------------------------------------------------
# generator + demand-response events
# ---------------------------------------------------------------------------


def test_generate_signals_deterministic_and_shaped():
    a = generate_signals(5, 7, seed=3)
    b = generate_signals(5, 7, seed=3)
    np.testing.assert_array_equal(a.carbon.values, b.carbon.values)
    np.testing.assert_array_equal(a.price.values, b.price.values)
    c = generate_signals(5, 7, seed=4)
    assert not np.array_equal(a.carbon.values, c.carbon.values)
    # traces cover 2*days (the simulator's late-job tail) and stay positive
    assert a.carbon.edges[-1] == 14 * 24 * HOUR
    assert (a.carbon.values >= 40.0).all()
    assert (a.price.values >= 0.0).all()
    # duck curve: evening mean tops midday mean
    hod = (np.arange(a.carbon.values.shape[1]) % 24)
    evening = a.carbon.values[:, hod == 19].mean()
    midday = a.carbon.values[:, hod == 13].mean()
    assert evening > midday + 100.0


def test_curtail_requests_track_carbon_peaks():
    sig = generate_signals(3, 3, seed=0, curtail_threshold=500.0,
                           curtail_frac=0.4)
    assert sig.curtailments  # the evening ramp crosses 500 somewhere
    for c in sig.curtailments:
        assert isinstance(c, CurtailRequest)
        assert c.power_frac == 0.4
        mid = 0.5 * (c.start_s + c.end_s)
        assert sig.carbon.value(c.site, mid) >= 500.0
        # maximality: the hour before the span (if any) is below threshold
        if c.start_s > 0:
            assert sig.carbon.value(c.site, c.start_s - 1.0) < 500.0
    # no threshold -> no events
    assert generate_signals(3, 3, seed=0).curtailments == ()


# ---------------------------------------------------------------------------
# ForecastHorizon signal queries
# ---------------------------------------------------------------------------


def make_fc(sig, windows=((WindowForecast(2 * HOUR, 5 * HOUR),),
                          (), (WindowForecast(30 * HOUR, 33 * HOUR),))):
    return ForecastHorizon(horizon_s=24 * HOUR, sigma_s=0.0,
                           site_windows=windows, outages=(), signals=sig)


def test_forecast_signal_queries():
    sig = generate_signals(3, 3, seed=5, curtail_threshold=500.0)
    fc = make_fc(sig)
    for t in (0.0, 3.3 * HOUR, 19 * HOUR, 40 * HOUR):
        grid = fc.carbon_grid(t)
        cfrac = fc.curtail_frac_grid(t)
        for s in range(3):
            assert float(grid[s]) == fc.carbon_value(s, t) \
                == sig.carbon.value(s, t)
            assert fc.price_value(s, t) == sig.price.value(s, t)
            c = fc.active_curtail(s, t)
            want = c.power_frac if c is not None else 1.0
            assert float(cfrac[s]) == want
            # next curtail START strictly after t, horizon-gated
            nxt = fc.next_curtail_start_s(s, t)
            future = [c2.start_s for c2 in sig.curtailments
                      if c2.site == s and t < c2.start_s < t + fc.horizon_s]
            assert nxt == (min(future) if future else float("inf"))
    # grid_carbon_g: window overlap is free, the rest integrates exactly
    g = fc.grid_carbon_g(0, HOUR, 6 * HOUR, 0.75)
    want = 0.75 / HOUR * (sig.carbon.integral(0, HOUR, 6 * HOUR)
                          - sig.carbon.integral(0, 2 * HOUR, 5 * HOUR))
    assert g == pytest.approx(want, rel=1e-12)
    # beyond-horizon window credit is gated (site 2's window at t=0 is
    # outside the 24 h lookahead -> fully grid-billed)
    g2 = fc.grid_carbon_g(2, 0.0, 33 * HOUR, 0.75)
    assert g2 == pytest.approx(
        0.75 / HOUR * sig.carbon.integral(2, 0.0, 33 * HOUR), rel=1e-12)


def test_forecast_without_signals_degrades_to_grid_seconds():
    fc = make_fc(None)
    assert fc.carbon_value(0, 0.0) == 0.0
    assert np.array_equal(fc.curtail_frac_grid(0.0), np.ones(3))
    assert fc.active_curtail(0, 0.0) is None
    # grid-seconds weighting: 5 h span minus the 3 h window at weight 1
    g = fc.grid_carbon_g(0, HOUR, 6 * HOUR, 1.0)
    assert g == pytest.approx(2 * HOUR / HOUR, rel=1e-9)


# ---------------------------------------------------------------------------
# simulator accounting invariants
# ---------------------------------------------------------------------------


SMALL = dict(days=3, n_jobs=60)


@pytest.mark.parametrize("scenario,policy", [
    ("carbon-peaks", "feasibility-aware"),
    ("paper-table6", "static"),
])
def test_site_breakdowns_sum_to_totals_exactly(scenario, policy):
    r = ClusterSimulator.from_scenario(scenario, policy,
                                       overrides=SMALL).run()
    assert r.grid_gco2 > 0.0 and r.grid_cost > 0.0
    assert sum(r.site_grid_gco2) == pytest.approx(r.grid_gco2, rel=1e-12)
    assert sum(r.site_grid_cost) == pytest.approx(r.grid_cost, rel=1e-12)
    s = r.summary()
    assert s["grid_gco2"] == round(r.grid_gco2, 1)
    assert len(s["site_grid_gco2"]) == 5


def test_signal_accounting_never_touches_kwh():
    """The refactor's hard invariant: grid/renewable kWh are bit-identical
    under any signal profile (the signal integral is parallel, not a
    rewrite of the energy path)."""
    base = ClusterSimulator.from_scenario("paper-table6", "feasibility-aware",
                                          overrides=SMALL).run()
    hot = ClusterSimulator.from_scenario(
        get_scenario("paper-table6").replace(
            signals=SignalProfile(carbon_base=900.0, carbon_evening=800.0)),
        "feasibility-aware", overrides=SMALL).run()
    assert hot.grid_kwh == base.grid_kwh
    assert hot.renewable_kwh == base.renewable_kwh
    assert hot.migrations == base.migrations
    assert hot.grid_gco2 > base.grid_gco2  # the signal DID change


def test_event_engine_signal_accounting_matches_fixed_dt():
    """Engine parity for the new accumulators: the event engine's exact
    per-span integrals agree with the fixed-dt rectangle rule within the
    usual engine tolerance, for a migration-free and a migration-heavy
    policy."""
    for policy in ("static", "feasibility-aware"):
        out = {}
        for engine in ("fixed-dt", "event"):
            out[engine] = ClusterSimulator.from_scenario(
                "carbon-peaks", policy,
                overrides=dict(engine=engine, **SMALL)).run()
        f, e = out["fixed-dt"], out["event"]
        assert e.grid_gco2 == pytest.approx(f.grid_gco2, rel=0.05)
        assert e.grid_cost == pytest.approx(f.grid_cost, rel=0.05)
        for s in range(5):
            assert e.site_grid_gco2[s] == pytest.approx(
                f.site_grid_gco2[s], rel=0.08, abs=500.0)


def test_gco2_weights_time_of_use_not_just_kwh():
    """A run billed against a flat carbon trace must reproduce
    grid_kwh * carbon exactly; the duck-curve default must differ from
    that flat-rate product (time-of-use matters)."""
    flat = get_scenario("paper-table6").replace(signals=SignalProfile(
        carbon_base=400.0, carbon_morning=0.0, carbon_evening=0.0,
        carbon_midday_dip=0.0, carbon_noise=0.0, carbon_site_spread=0.0))
    r = ClusterSimulator.from_scenario(flat, "feasibility-aware",
                                       overrides=SMALL).run()
    assert r.grid_gco2 == pytest.approx(400.0 * r.grid_kwh, rel=1e-9)
    duck = ClusterSimulator.from_scenario("paper-table6", "feasibility-aware",
                                          overrides=SMALL).run()
    assert duck.grid_kwh == r.grid_kwh
    assert duck.grid_gco2 != pytest.approx(400.0 * duck.grid_kwh, rel=1e-3)


# ---------------------------------------------------------------------------
# receding-horizon: parity + acceptance
# ---------------------------------------------------------------------------


def test_receding_horizon_parity_inside_simulation():
    """decide == decide_scalar action-for-action on every orchestrator
    tick of real runs across the new scenarios (the in-situ complement of
    the random-state parity in tests/test_vectorized.py)."""
    from repro.core.orchestrator import RecedingHorizonPolicy

    class Checked(RecedingHorizonPolicy):
        checks = 0

        def decide(self, state):
            got = super().decide(state)
            want = self.decide_scalar(state)
            assert got == want, (state.t, got, want)
            Checked.checks += 1
            return got

    for scn in ("carbon-peaks", "demand-response"):
        r = ClusterSimulator.from_scenario(
            scn, Checked(), overrides=dict(days=2, n_jobs=40)).run()
        assert r.completed == 40
    assert Checked.checks > 100


def test_receding_horizon_honours_curtail_requests():
    """On demand-response, running jobs get throttled to the requested cap
    during DR spans — visible as 0.3/0.4-level power fractions and a lower
    gCO2 than the signal-blind planner."""
    rh = ClusterSimulator.from_scenario("demand-response", "receding-horizon",
                                        overrides=SMALL).run()
    pa = ClusterSimulator.from_scenario("demand-response", "plan-ahead",
                                        overrides=SMALL).run()
    assert rh.completed == pa.completed == 60
    assert rh.grid_gco2 < pa.grid_gco2
    assert rh.rejected_actions == 0


def test_receding_horizon_beats_plan_ahead_on_carbon_peaks_sweep():
    """The acceptance bar: >= 8 seeds of full 7-day carbon-peaks runs,
    receding-horizon's mean grid_gco2 strictly below plan-ahead's with
    non-overlapping 95% CIs, completed jobs no worse."""
    from repro.core.sweep import SweepSpec, run_sweep

    spec = SweepSpec(scenarios=("carbon-peaks",),
                     policies=("plan-ahead", "receding-horizon"),
                     seeds=tuple(range(8)))
    agg = run_sweep(spec, keep_results=False).aggregate()
    pa = agg[("carbon-peaks", "plan-ahead")]
    rh = agg[("carbon-peaks", "receding-horizon")]
    assert (rh["grid_gco2"]["mean"] + rh["grid_gco2"]["ci95"]
            < pa["grid_gco2"]["mean"] - pa["grid_gco2"]["ci95"])
    assert rh["completed"]["mean"] >= pa["completed"]["mean"]


@pytest.mark.parametrize("name", ["carbon-peaks", "price-spread",
                                  "demand-response"])
def test_new_scenarios_run_end_to_end(name):
    r = ClusterSimulator.from_scenario(
        name, "receding-horizon", overrides=dict(days=2, n_jobs=24)).run()
    assert r.completed == 24
    assert r.rejected_actions == 0
    assert r.grid_gco2 > 0.0


def test_price_spread_scenario_spreads_site_costs():
    r = ClusterSimulator.from_scenario("price-spread", "static",
                                       overrides=SMALL).run()
    rates = [c / g * 1000.0 for c, g in zip(r.site_grid_cost, r.site_grid_gco2)
             if g > 0]
    assert max(rates) > 1.3 * min(rates)  # $ per kg separates the sites


def test_cluster_state_carries_signal_grids():
    sim = ClusterSimulator.from_scenario("carbon-peaks", "static",
                                         overrides=dict(days=2, n_jobs=8))
    t = 19 * HOUR
    state = sim.snapshot(t)
    assert state.site_carbon.shape == (5,)
    assert (state.site_carbon > 0).all()
    np.testing.assert_array_equal(state.site_carbon,
                                  sim.signals.carbon.value_grid(t))
    assert len(state.job_carbon) == len(state.soa)
    np.testing.assert_array_equal(state.job_carbon,
                                  state.site_carbon[state.soa.site])
    np.testing.assert_array_equal(state.site_price,
                                  sim.signals.price.value_grid(t))
    np.testing.assert_array_equal(state.site_curtail_frac,
                                  sim.forecast_horizon.curtail_frac_grid(t))
    # a signal-free snapshot degrades to zeros / ones
    from repro.core.state import ClusterState, SiteView
    bare = ClusterState.build(0.0, [], [SiteView(0, 4, 0, 0, True, HOUR)],
                              nic_bps=1e9)
    assert bare.site_carbon.tolist() == [0.0]
    assert bare.site_price.tolist() == [0.0]
    assert bare.site_curtail_frac.tolist() == [1.0]
