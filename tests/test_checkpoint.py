"""Checkpoint serializer/manager + migration engine: roundtrips, size
accounting (the feasibility model's S_j), compression ratios, elastic
restore, end-to-end migration."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager, serialize_tree, deserialize_tree, tree_bytes
from repro.checkpoint.serializer import from_bytes, to_bytes
from repro.core import feasibility as fz
from repro.core.migration import migrate_job


def make_tree(seed=0, scale=1.0):
    k = jax.random.PRNGKey(seed)
    ks = jax.random.split(k, 4)
    return {
        "w": jax.random.normal(ks[0], (128, 64), jnp.float32) * scale,
        "b": jax.random.normal(ks[1], (64,), jnp.float32),
        "emb": {"table": jax.random.normal(ks[2], (1000, 32), jnp.bfloat16)},
        "step": jnp.int32(7),
    }


def test_full_roundtrip_exact():
    tree = make_tree()
    payload = serialize_tree(tree, mode="full")
    back = deserialize_tree(payload, tree)
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(back)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_bytes_roundtrip():
    tree = make_tree()
    payload = serialize_tree(tree, mode="full")
    again = from_bytes(to_bytes(payload))
    assert again.manifest == payload.manifest
    assert again.data == payload.data


def test_int8_compresses_and_bounded_error():
    tree = make_tree()
    raw = tree_bytes(tree)
    payload = serialize_tree(tree, mode="int8")
    # f32 leaves shrink ~4x; bf16 ~2x; int leaves stay raw
    assert len(payload.data) < 0.45 * raw
    back = deserialize_tree(payload, tree)
    err = float(jnp.max(jnp.abs(back["w"] - tree["w"])))
    amax = float(jnp.max(jnp.abs(tree["w"])))
    assert err <= amax / 127
    np.testing.assert_array_equal(np.asarray(back["step"]), np.asarray(tree["step"]))


def test_delta_int8_roundtrip():
    base = make_tree(0)
    stepped = jax.tree.map(
        lambda x: x + 0.01 if jnp.issubdtype(x.dtype, jnp.floating) else x, base
    )
    payload = serialize_tree(stepped, mode="delta-int8", base=base)
    back = deserialize_tree(payload, stepped, base=base)
    err = float(jnp.max(jnp.abs(back["w"] - stepped["w"])))
    assert err < 1e-3  # delta range is tiny -> tiny quant error


def test_manager_save_restore_and_retention(tmp_path):
    mgr = CheckpointManager(str(tmp_path), job="j1", keep=2)
    tree = make_tree()
    for step in (10, 20, 30):
        mgr.save(step, tree)
    assert len(mgr._history) == 2  # retention
    assert mgr.latest.step == 30
    assert mgr.latest_bytes > 0
    back, info = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    assert info.step == 30


def test_manager_async_save(tmp_path):
    mgr = CheckpointManager(str(tmp_path), job="j2", async_save=True)
    tree = make_tree()
    mgr.save(1, tree)
    mgr.wait()
    assert mgr.latest_bytes > 0
    back, _ = mgr.restore(tree)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


def test_measured_size_feeds_feasibility(tmp_path):
    """The orchestrator's S_j is the measured serialized size."""
    mgr = CheckpointManager(str(tmp_path), job="j3")
    tree = make_tree()
    mgr.save(1, tree)
    S = mgr.latest_bytes
    assert abs(S - tree_bytes(tree)) / tree_bytes(tree) < 0.1  # manifest overhead only
    v = fz.evaluate(S, 10e9, 2.5 * 3600)
    assert bool(v.feasible)  # tiny tree: class A


def test_migration_end_to_end(tmp_path):
    """save -> WAN model -> import at destination -> restore: identical
    state, report terms match eq. (1)."""
    src_root, dst_root = str(tmp_path / "siteA"), str(tmp_path / "siteB")
    mgr = CheckpointManager(src_root, job="trainjob")
    tree = make_tree()
    mgr.save(42, tree)
    dst, report = migrate_job(mgr, dst_root, bandwidth_bps=1e9, window_s=2.5 * 3600)
    assert report.step == 42
    assert report.workload_class == 0
    assert report.feasible_in_window is True
    assert report.t_transfer_s == pytest.approx(8 * report.nbytes / 1e9, rel=1e-6)
    assert report.t_cost_s == pytest.approx(
        report.t_transfer_s + fz.T_LOAD_S + fz.T_DOWNTIME_S, rel=1e-6
    )
    back, _ = dst.restore(tree)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))


def test_elastic_restore_with_shardings(tmp_path):
    """Restore places leaves onto a new mesh (migration to a different
    slice)."""
    from jax.sharding import NamedSharding, PartitionSpec as P

    from repro.launch.mesh import make_mesh

    mesh = make_mesh((1,), ("data",))
    mgr = CheckpointManager(str(tmp_path), job="j4")
    tree = make_tree()
    mgr.save(1, tree)
    sh = jax.tree.map(lambda _: NamedSharding(mesh, P()), tree)
    back, _ = mgr.restore(tree, shardings=sh)
    assert all(x.sharding == NamedSharding(mesh, P()) for x in jax.tree.leaves(back))
