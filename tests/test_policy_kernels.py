"""Parity of the batched/compiled decide kernels (policy_kernels).

The contract under test: for arbitrary cluster states, the cross-cell
batched kernel path produces exactly what the per-cell numpy grids
produce, which in turn produce exactly what the per-job scalar oracle
(``decide_scalar``) produces — one chain of bit-identical Action lists,
with the padded batch lanes never leaking into a real row's verdict.

Runs as a seeded property-style suite; when hypothesis is installed the
same properties also run under its generator.
"""
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
    HAS_HYPOTHESIS = True
except ImportError:  # clean environments: deterministic tests still run
    HAS_HYPOTHESIS = False

from repro.core import policy_kernels as pk
from repro.core.orchestrator import FeasibilityAwarePolicy, score_migrations
from repro.core.state import ClusterState, JobView, SiteView
from tests.test_vectorized import random_state

GB = 1e9
HOUR = 3600.0

PARAM_SETS = [
    dict(),
    dict(min_benefit_s=0.0),
    dict(eps=0.05, forecast_sigma_s=900.0),
]


def _cells(seed, n_cells):
    """A batch of random cells with their candidate rows (live cells
    only, mirroring what ``decide_batch`` feeds ``score_states``)."""
    pol = FeasibilityAwarePolicy()
    states, cands = [], []
    for i in range(n_cells):
        s = random_state(seed * 101 + i)
        c = pol._prep(s)
        if c is not None:
            states.append(s)
            cands.append(c)
    return states, cands


@pytest.mark.parametrize("seed", range(12))
def test_batch_from_states_matches_per_cell_rows(seed):
    """The one-pass cross-cell gather builds the exact ScoreBatch of the
    per-cell rows_from_state + build_batch path."""
    states, cands = _cells(seed, 5)
    if not states:
        pytest.skip("no live cells at this seed")
    got = pk.batch_from_states(states, cands)
    want = pk.build_batch(
        [pk.rows_from_state(s, c) for s, c in zip(states, cands)])
    assert got.n_jobs == want.n_jobs and got.n_sites == want.n_sites
    for f in ("sizes", "t_loads", "rem", "s_i", "cur_green", "load_src",
              "bw", "W", "bq_load", "free_slots"):
        np.testing.assert_array_equal(getattr(got, f), getattr(want, f), f)


@pytest.mark.parametrize("kwargs", PARAM_SETS)
@pytest.mark.parametrize("seed", range(12))
def test_score_states_matches_per_cell_score_migrations(seed, kwargs):
    """Batched multi-cell dests == per-cell fused numpy grids."""
    pol = FeasibilityAwarePolicy(**kwargs)
    states, cands = _cells(seed, 5)
    if not states:
        pytest.skip("no live cells at this seed")
    dests = pk.score_states(states, cands, pol._params())
    for s, c, got in zip(states, cands, dests):
        _, _, want = score_migrations(
            s, c, s.bandwidth_bps[s.soa.site[c], :], alpha=pol.alpha,
            eps=pol.eps, forecast_sigma_s=pol.forecast_sigma_s,
            gamma=pol.gamma, beta=pol.beta,
            queue_penalty_s=pol.queue_penalty_s,
            min_benefit_s=pol.min_benefit_s)
        if want is None:
            assert got is None or not (np.asarray(got) >= 0).any()
        else:
            assert got is not None
            np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("backend", ["jit", "pallas"])
@pytest.mark.parametrize("seed", range(12))
def test_compiled_backends_match_numpy_dest(seed, backend):
    """jit (float64 XLA) and pallas (tiled, interpret off-TPU) resolve
    the same argbest destinations as the numpy oracle."""
    states, cands = _cells(seed, 4)
    if not states:
        pytest.skip("no live cells at this seed")
    params = FeasibilityAwarePolicy()._params()
    batch = pk.batch_from_states(states, cands)
    want = pk.score_batch(batch, params, "numpy")
    got = pk.score_batch(batch, params, backend)
    np.testing.assert_array_equal(np.asarray(got), want)


@pytest.mark.parametrize("backend", ["jit", "pallas"])
@pytest.mark.parametrize("seed", range(10))
def test_backend_decide_matches_scalar_oracle(seed, backend):
    """End-to-end: Policy.decide under a compiled backend emits the
    bit-identical Action list of decide_scalar (reservation walk
    included)."""
    state = random_state(seed)
    pol = FeasibilityAwarePolicy()
    want = pol.decide_scalar(state)
    pk.set_backend(backend)
    try:
        got = pol.decide(state)
    finally:
        pk.set_backend(None)
    assert got == want


@pytest.mark.parametrize("seed", range(10))
def test_decide_batch_matches_per_cell_decide(seed):
    """The sweep runner's entry point: one fused pass over many cells
    == per-cell decide == per-cell decide_scalar."""
    pol = FeasibilityAwarePolicy()
    states = [random_state(seed * 31 + i) for i in range(6)]
    got = pol.decide_batch(states)
    assert got == [pol.decide(s) for s in states]
    assert got == [pol.decide_scalar(s) for s in states]


# ---------------------------------------------------------------------------
# padded-lane edge cases
# ---------------------------------------------------------------------------


def _mini_state(n_sites, jobs, t=1.0 * HOUR, green=None):
    sites = [
        SiteView(sid=s, slots=4, busy=1, queued=0,
                 renewable_active=bool(green[s]) if green else False,
                 window_remaining_s=6.0 * HOUR if green and green[s] else 0.0,
                 incoming=0, next_window_start_s=t + 2 * HOUR)
        for s in range(n_sites)
    ]
    return ClusterState.build(t, jobs, sites, nic_bps=2e9)


def test_all_dark_tick_short_circuits():
    """No positive window anywhere: _prep bails before any kernel work
    and decide returns no actions on every backend."""
    jobs = [JobView(jid=0, site=0, ckpt_bytes=10 * GB,
                    remaining_compute_s=4 * HOUR, state="running")]
    state = _mini_state(3, jobs)
    pol = FeasibilityAwarePolicy()
    assert pol._prep(state) is None
    for backend in ("numpy", "jit", "pallas"):
        pk.set_backend(backend)
        try:
            assert pol.decide(state) == []
        finally:
            pk.set_backend(None)
    assert pol.decide_scalar(state) == []


def test_zero_feasible_destinations_returns_none_cell():
    """A live cell whose rows all fail feasibility yields a None dest
    list entry (the batched no-migration fast path), and an empty
    Action list end to end."""
    # green destination exists but the checkpoint is far too large to
    # move inside any window at nic_bps=2e9
    jobs = [JobView(jid=0, site=0, ckpt_bytes=4000 * GB,
                    remaining_compute_s=12 * HOUR, state="running")]
    state = _mini_state(3, jobs, green=[False, True, False])
    pol = FeasibilityAwarePolicy()
    cand = pol._prep(state)
    assert cand is not None
    dests = pk.score_states([state], [cand], pol._params())
    assert dests == [None]
    assert pol.decide(state) == [] == pol.decide_scalar(state)


def test_single_job_cells_batch():
    """k=1 cells pad up to the minimum job bucket; the padded rows must
    never surface as actions."""
    pol = FeasibilityAwarePolicy()
    states = []
    for i in range(4):
        jobs = [JobView(jid=7, site=0, ckpt_bytes=(5 + i) * GB,
                        remaining_compute_s=8 * HOUR, state="running")]
        states.append(_mini_state(3, jobs, green=[False, True, i % 2 == 0]))
    got = pol.decide_batch(states)
    assert got == [pol.decide_scalar(s) for s in states]
    assert all(len(acts) <= 1 for acts in got)
    assert any(got)  # the setup admits at least one migration


def test_padding_buckets_reuse_shapes():
    """Job-count drift inside one power-of-two bucket must not change
    the padded shape (the no-recompile guarantee)."""
    assert pk.pad_jobs(1) == pk.pad_jobs(8) == 8
    assert pk.pad_jobs(9) == pk.pad_jobs(16) == 16
    assert pk.pad_sites(3) == pk.pad_sites(8) == 8
    assert pk.pad_sites(9) == 16


if HAS_HYPOTHESIS:
    @settings(max_examples=60, deadline=None)
    @given(st.integers(0, 10_000))
    def test_decide_batch_matches_scalar_hypothesis(seed):
        pol = FeasibilityAwarePolicy()
        states = [random_state(seed * 17 + i) for i in range(4)]
        assert pol.decide_batch(states) == [
            pol.decide_scalar(s) for s in states]
