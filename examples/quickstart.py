"""Quickstart: the feasibility-domain model + one orchestration decision
through the typed Action / ClusterState API.

  PYTHONPATH=src python examples/quickstart.py
"""
from repro.core import feasibility as fz
from repro.core import (
    ClusterState, FeasibilityAwarePolicy, JobView, SiteView, make_policy,
    available_policies, available_scenarios,
)

GB = 1e9

# --- 1. the paper's core equations ----------------------------------------
for size_gb in (1, 6, 40, 280):
    v = fz.evaluate(size_gb * GB, 10e9, window_s=2.5 * 3600)
    print(
        f"{size_gb:>4} GB @10Gbps: T_transfer={float(v.t_transfer_s):7.1f}s  "
        f"T_cost={float(v.t_cost_s):7.1f}s  T_breakeven={float(v.t_breakeven_s):6.1f}s  "
        f"class={'ABC'[int(v.workload_class)]}  feasible={bool(v.feasible)}"
    )

# --- 2. one Algorithm-1 decision -------------------------------------------
# ClusterState.build is the one snapshot constructor shared by the
# simulator, the dry-run planner and the serve router. With no in-flight
# transfers the advertised bandwidth matrix is the full per-NIC rate.
job = JobView(jid=0, site=0, ckpt_bytes=6 * GB, remaining_compute_s=4 * 3600)
sites = [
    SiteView(0, slots=4, busy=3, queued=2, renewable_active=False, window_remaining_s=0),
    SiteView(1, slots=4, busy=1, queued=0, renewable_active=True, window_remaining_s=3 * 3600),
    SiteView(2, slots=4, busy=4, queued=3, renewable_active=True, window_remaining_s=8 * 3600),
]
state = ClusterState.build(t=0.0, jobs=[job], sites=sites, nic_bps=10e9)
actions = FeasibilityAwarePolicy().decide(state)
print("\nAlgorithm 1 decision:", actions,
      "-> Migrate to the green, *uncongested* site (site 1), not the greener"
      " but congested site 2")

# --- 3. the policy & scenario registries -----------------------------------
print("\nregistered policies: ", ", ".join(available_policies()))
print("registered scenarios:", ", ".join(available_scenarios()))
throttle = make_policy("grid-throttle", power_frac=0.4)
print("grid-throttle on a dark site:",
      throttle.decide(ClusterState.build(
          t=0.0,
          jobs=[JobView(7, 0, 2 * GB, 3600.0, state="running")],
          sites=[SiteView(0, 4, 1, 0, False, 0.0)],
          nic_bps=10e9)))

# --- 4. stochastic feasibility (§VI.H) -------------------------------------
for eps in (0.5, 0.05, 0.01):
    ok = bool(fz.stochastic_feasible(40 * GB, 1e9, window_forecast_s=3600,
                                     window_sigma_s=900, eps=eps))
    print(f"40GB@1Gbps, 1h±15min window, eps={eps}: migrate={ok}")
