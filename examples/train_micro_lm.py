"""End-to-end driver: train a ~100M-parameter LM for a few hundred steps
with periodic checkpointing and ONE live feasibility-gated migration between
two micro-datacenter sites mid-run. Loss decreases across the migration;
final state is identical to an unmigrated run (asserted).

Full run (the deliverable shape; ~100M params, 300 steps):
  PYTHONPATH=src python examples/train_micro_lm.py --arch micro-lm-100m --steps 300

CPU-container demo (seconds):
  PYTHONPATH=src python examples/train_micro_lm.py --demo
"""
import argparse
import json
import os
import tempfile
import time

import jax

from repro.checkpoint import CheckpointManager
from repro.configs import get_config
from repro.core import feasibility as fz
from repro.core.migration import migrate_job
from repro.data.pipeline import SyntheticLMDataset
from repro.models import build_model
from repro.optim.adamw import AdamWConfig
from repro.train import Trainer, TrainerConfig, TrainStepConfig


def make_trainer(model, cfg, root, site, steps, batch, seq, lr):
    data = SyntheticLMDataset(cfg.vocab_size, seq, batch, seed=0)
    ckpt = CheckpointManager(os.path.join(root, site), job="lm100m", mode="full")
    return Trainer(
        model, data, ckpt,
        TrainerConfig(
            total_steps=steps, save_every=max(steps // 6, 10), log_every=max(steps // 12, 5),
            step_cfg=TrainStepConfig(opt=AdamWConfig(lr=lr), total_steps=steps,
                                     warmup_steps=max(steps // 20, 3)),
        ),
    )


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="micro-lm-100m")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--batch", type=int, default=2)
    ap.add_argument("--seq", type=int, default=64)
    ap.add_argument("--lr", type=float, default=1e-3)
    ap.add_argument("--demo", action="store_true", help="tiny CPU demo config")
    ap.add_argument("--wan-gbps", type=float, default=10.0)
    args = ap.parse_args()

    cfg = get_config(args.arch)
    if args.demo:
        cfg = get_config("micro-lm").reduced()
        args.steps = min(args.steps, 60)
    model = build_model(cfg)
    n_params = sum(
        x.size for x in jax.tree.leaves(jax.eval_shape(model.init, jax.random.PRNGKey(0)))
    )
    print(f"[example] arch={cfg.name} params={n_params/1e6:.1f}M steps={args.steps}")

    root = tempfile.mkdtemp(prefix="greenflow_sites_")
    mid = args.steps // 2

    # --- site A: train until the renewable window closes at mid-run --------
    a = make_trainer(model, cfg, root, "siteA", args.steps, args.batch, args.seq, args.lr)
    a.preempt_signal = lambda step: step >= mid
    t0 = time.time()
    sa = a.run()
    print(f"[example] site A preempted at step {sa['step']} "
          f"(loss {sa['loss']:.3f}, {time.time()-t0:.1f}s)")

    # --- orchestrator: feasibility gate on the MEASURED checkpoint ---------
    S = a.ckpt.latest_bytes
    verdict = fz.evaluate(S, args.wan_gbps * 1e9, window_s=2.5 * 3600)
    print(f"[example] checkpoint S={S/1e6:.1f} MB, class "
          f"{'ABC'[int(verdict.workload_class)]}, T_cost={float(verdict.t_cost_s):.1f}s, "
          f"feasible={bool(verdict.feasible)}")
    assert bool(verdict.feasible), "migration must be feasible for this job size"

    dst, report = migrate_job(a.ckpt, os.path.join(root, "siteB"),
                              bandwidth_bps=args.wan_gbps * 1e9, window_s=2.5 * 3600)
    print(f"[example] migrated: T_transfer={report.t_transfer_s:.2f}s modeled, "
          f"serialize={report.t_serialize_s:.2f}s measured, class "
          f"{'ABC'[report.workload_class]}")

    # --- site B: restore and finish ----------------------------------------
    b = make_trainer(model, cfg, root, "siteB", args.steps, args.batch, args.seq, args.lr)
    b.ckpt = dst
    resumed = b.restore()
    assert resumed == mid
    sb = b.run()
    print(f"[example] site B finished at step {sb['step']} (loss {sb['loss']:.3f})")
    hist = a.history + b.history
    print("[example] loss curve:", json.dumps(
        [{"step": h["step"], "loss": round(h["loss"], 3)} for h in hist]))
    first, last = hist[0]["loss"], hist[-1]["loss"]
    assert last < first, "loss must decrease across the migration"
    print(f"[example] OK: loss {first:.3f} -> {last:.3f} across a live migration")


if __name__ == "__main__":
    main()
