"""Green-cluster simulation driven by the scenario registry: renewable-
window timeline + the paper's policy comparison (Table VI/VIII) on one
shared trace, for any registered scenario.

  PYTHONPATH=src python examples/green_cluster_sim.py
  PYTHONPATH=src python examples/green_cluster_sim.py --scenario flaky-wan
  PYTHONPATH=src python examples/green_cluster_sim.py --list
"""
import argparse

from repro.core import (
    available_scenarios, get_scenario, run_policy_comparison, trace_stats,
)

HOUR = 3600.0


def ascii_timeline(traces, days, width=96):
    total = days * 24 * HOUR
    lines = []
    for tr in traces:
        cells = []
        for i in range(width):
            t = total * i / width
            cells.append("#" if tr.active(t) else ".")
        lines.append(f"site{tr.site} |{''.join(cells)}|")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--scenario", default="paper-table6",
                    help=f"one of: {', '.join(available_scenarios())}")
    ap.add_argument("--list", action="store_true", help="list scenarios and exit")
    ap.add_argument("--days", type=int, default=None)
    ap.add_argument("--jobs", type=int, default=None)
    ap.add_argument("--wan", type=float, default=None,
                    help="override the scenario's per-NIC Gbps (tip: 1.0 on "
                         "paper-table6 is the paper's sharpest ordering "
                         "regime, see EXPERIMENTS.md)")
    ap.add_argument("--dt", type=float, default=None,
                    help="fixed-dt engine step (only with --engine fixed-dt)")
    ap.add_argument("--engine", default=None, choices=["event", "fixed-dt"],
                    help="time stepping: next-event (default) or legacy fixed-dt")
    ap.add_argument("--failures", type=float, default=None,
                    help="node failures per slot-hour (overrides the scenario)")
    args = ap.parse_args()

    if args.list:
        for name in available_scenarios():
            scn = get_scenario(name)
            print(f"{name:<18} {scn.description}")
        return

    scn = get_scenario(args.scenario)
    print(f"scenario {scn.name!r}: {scn.description}")
    overrides = {}
    if args.wan is not None:
        overrides["wan_gbps"] = args.wan
    if args.dt is not None:
        overrides["dt_s"] = args.dt
    if args.engine is not None:
        overrides["engine"] = args.engine
    if args.days is not None:
        overrides["days"] = args.days
    if args.jobs is not None:
        overrides["n_jobs"] = args.jobs
    if args.failures is not None:
        overrides["failure_rate_per_slot_hour"] = args.failures
    cfg = scn.sim_config(**overrides)

    from repro.core import generate_trace

    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed, profile=cfg.trace)
    print("renewable-surplus windows (# = surplus):")
    print(ascii_timeline(traces, cfg.days))
    print("trace stats:", trace_stats(traces))

    print("\nrunning 4 policies on the shared trace ...")
    results = run_policy_comparison(cfg)
    print(f"{'policy':<18} {'nonrenew':>8} {'JCT':>6} {'migr-ovh':>9} "
          f"{'stalls':>7} {'renew%':>7} {'migr':>5} {'failed':>6}")
    base = results["static"]
    for name, r in results.items():
        print(f"{name:<18} {r.grid_kwh/base.grid_kwh:>8.2f} "
              f"{r.mean_jct_s/base.mean_jct_s:>6.2f} {r.migration_overhead:>9.1%} "
              f"{r.stall_overhead:>7.1%} {r.renewable_fraction:>7.1%} "
              f"{r.migrations:>5d} {r.failed_migrations:>6d}")
    print("\npaper Table VI: static 1.00/1.00/0% | energy-only 0.62/1.35/18% |")
    print("               feasibility-aware 0.48/0.82/<2% | oracle 0.40/0.79/<2%")


if __name__ == "__main__":
    main()
