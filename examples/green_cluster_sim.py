"""7-day green-cluster simulation: renewable-window timeline + the paper's
policy comparison (Table VI/VIII) on one shared trace.

  PYTHONPATH=src python examples/green_cluster_sim.py [--days 7] [--wan 1.0]
"""
import argparse

from repro.core import (
    SimConfig, generate_trace, normalized_table, run_policy_comparison,
    trace_stats,
)

HOUR = 3600.0


def ascii_timeline(traces, days, width=96):
    total = days * 24 * HOUR
    lines = []
    for tr in traces:
        cells = []
        for i in range(width):
            t = total * i / width
            cells.append("#" if tr.active(t) else ".")
        lines.append(f"site{tr.site} |{''.join(cells)}|")
    return "\n".join(lines)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--days", type=int, default=7)
    ap.add_argument("--jobs", type=int, default=240)
    ap.add_argument("--wan", type=float, default=1.0,
                    help="effective per-flow WAN Gbps (see EXPERIMENTS.md)")
    ap.add_argument("--dt", type=float, default=60.0)
    ap.add_argument("--failures", type=float, default=0.0,
                    help="node failures per slot-hour (beyond-paper fault injection)")
    args = ap.parse_args()

    cfg = SimConfig(days=args.days, n_jobs=args.jobs, wan_gbps=args.wan,
                    dt_s=args.dt, failure_rate_per_slot_hour=args.failures)
    traces = generate_trace(cfg.n_sites, cfg.days, seed=cfg.seed)
    print("renewable-surplus windows (# = surplus):")
    print(ascii_timeline(traces, args.days))
    print("trace stats:", trace_stats(traces))

    print("\nrunning 4 policies on the shared trace ...")
    results = run_policy_comparison(cfg)
    print(f"{'policy':<18} {'nonrenew':>8} {'JCT':>6} {'migr-ovh':>9} "
          f"{'stalls':>7} {'renew%':>7} {'migr':>5} {'failed':>6}")
    base = results["static"]
    for name, r in results.items():
        print(f"{name:<18} {r.grid_kwh/base.grid_kwh:>8.2f} "
              f"{r.mean_jct_s/base.mean_jct_s:>6.2f} {r.migration_overhead:>9.1%} "
              f"{r.stall_overhead:>7.1%} {r.renewable_fraction:>7.1%} "
              f"{r.migrations:>5d} {r.failed_migrations:>6d}")
    print("\npaper Table VI: static 1.00/1.00/0% | energy-only 0.62/1.35/18% |")
    print("               feasibility-aware 0.48/0.82/<2% | oracle 0.40/0.79/<2%")


if __name__ == "__main__":
    main()
