"""Checkpoint-migration microscope: serialize a real training state in all
three modes (full / int8 / delta-int8), push each through the WAN model at
several bandwidths, and show how compression moves the job across the
paper's feasibility classes — §VIII 'expanding the feasible envelope',
implemented.

  PYTHONPATH=src python examples/migrate_across_sites.py
"""
import os
import tempfile

import jax
import jax.numpy as jnp

from repro.checkpoint import CheckpointManager
from repro.checkpoint.serializer import serialize_tree, tree_bytes
from repro.configs import get_config
from repro.core import feasibility as fz
from repro.core.migration import migrate_job
from repro.models import build_model
from repro.optim.adamw import init_opt_state

GB = 1e9


def main():
    cfg = get_config("micro-lm").reduced()
    model = build_model(cfg)
    params = model.init(jax.random.PRNGKey(0))
    state = {"params": params, "opt": init_opt_state(params)}
    stepped = jax.tree.map(
        lambda x: x + 1e-3 if jnp.issubdtype(x.dtype, jnp.floating) else x, state
    )
    raw = tree_bytes(state)
    print(f"train state: {raw/1e6:.2f} MB raw")
    sizes = {
        "full": serialize_tree(stepped, mode="full").nbytes,
        "int8": serialize_tree(stepped, mode="int8").nbytes,
        "delta-int8": serialize_tree(stepped, mode="delta-int8", base=state).nbytes,
    }
    print(f"{'mode':<12} {'bytes':>12} {'ratio':>7}   class @ 1Gbps for a 32B-model-scale state")
    for mode, n in sizes.items():
        scale = 32.8e9 * 14 / raw  # what this mode would weigh at qwen2.5-32b scale
        big = n * scale
        cls = "ABC"[int(fz.classify(big, 1e9))]
        print(f"{mode:<12} {n:>12,} {raw/n:>6.1f}x   {big/GB:8.1f} GB -> class {cls}")

    # real end-to-end migration of the checkpoint artifact
    root = tempfile.mkdtemp(prefix="greenflow_migrate_")
    mgr = CheckpointManager(os.path.join(root, "A"), job="demo", mode="delta-int8")
    mgr.save(1, state)
    mgr.save(2, stepped)  # delta vs step-1 base
    print(f"\ndelta checkpoint on disk: {mgr.latest_bytes:,} bytes")
    for bw in (0.1e9, 1e9, 10e9):
        dst, rep = migrate_job(mgr, os.path.join(root, f"B{int(bw/1e6)}"),
                               bandwidth_bps=bw, window_s=2.5 * 3600)
        print(f"  @{bw/1e9:5.1f} Gbps: T_transfer={rep.t_transfer_s:8.3f}s "
              f"class={'ABC'[rep.workload_class]} feasible={rep.feasible_in_window}")


if __name__ == "__main__":
    main()
